"""Per-architecture reduced-config smoke tests: one forward + one train
step on CPU, asserting output shapes and finiteness (assignment §f)."""

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import get_model, make_batch
from repro.optim import adamw
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)

# Heavy configs (>5s each on CPU) ride in the slow lane; the tier-1 gate
# keeps one dense, one small-dense and one hybrid representative fast.
_HEAVY = {"zamba2-2.7b", "whisper-base", "phi3.5-moe-42b-a6.6b", "rwkv6-3b",
          "qwen2-moe-a2.7b", "qwen2-72b", "chameleon-34b"}


def _arch_params(heavy=_HEAVY):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in list_archs()]


@pytest.mark.parametrize("arch", _arch_params())
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, KEY, 2, 16)

    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))

    step = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=1e-3)))
    opt = adamw.init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # parameters actually moved and stayed finite
    moved = jtu.tree_map(lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jtu.tree_leaves(moved))
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jtu.tree_leaves(params2))


@pytest.mark.parametrize(
    "arch", _arch_params(heavy={"zamba2-2.7b", "whisper-base",
                                "phi3.5-moe-42b-a6.6b", "qwen2-moe-a2.7b",
                                "rwkv6-3b"}))
def test_prefill_decode_shapes(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, KEY, 2, 16)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    fixed = model.init_cache(2, 32)

    def splice(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        sl = tuple(slice(0, s) for s in src.shape)
        return dst.at[sl].set(src.astype(dst.dtype))

    cache = jtu.tree_map(splice, fixed, cache)
    nt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, nt)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"]) == 17


@pytest.mark.parametrize("arch", [
    "qwen2-72b", "nemotron-4-15b",
    pytest.param("whisper-base", marks=pytest.mark.slow),
])
def test_decode_matches_prefill_exactly(arch):
    """Teacher-forcing consistency for non-MoE archs (MoE drops tokens by
    capacity, so equality is not expected there)."""
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, KEY, 2, 16)
    logits, cache = jax.jit(model.prefill)(params, batch)
    nt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    fixed = model.init_cache(2, 32)
    cache = jtu.tree_map(
        lambda d, s: s if d.shape == s.shape
        else d.at[tuple(slice(0, x) for x in s.shape)].set(s.astype(d.dtype)),
        fixed, cache)
    logits2, _ = jax.jit(model.decode_step)(params, cache, nt)

    batch17 = dict(batch)
    batch17["tokens"] = jnp.concatenate([batch["tokens"], nt], axis=1)
    l17, _ = jax.jit(model.prefill)(params, batch17)
    assert float(jnp.max(jnp.abs(l17 - logits2))) < 2e-2


def test_param_counts_roughly_match_billing():
    """Sanity: full-config param counts are within 20% of the headline."""
    from repro.configs import get_config

    expectations = {
        "qwen2-72b": 72e9, "qwen2-7b": 7.6e9, "qwen2.5-3b": 3.1e9,
        "nemotron-4-15b": 15e9, "chameleon-34b": 34e9,
        "rwkv6-3b": 3.1e9, "zamba2-2.7b": 2.7e9,
    }
    for arch, expect in expectations.items():
        got = get_config(arch).param_count()
        assert 0.6 * expect < got < 1.6 * expect, (arch, got, expect)


@pytest.mark.slow
def test_rwkv_chunked_matches_scan():
    """Chunkwise-parallel RWKV6 == per-token scan (the §Perf cell-B
    optimization must be an exact reformulation)."""
    import dataclasses
    import numpy as np

    cfg = smoke_config("rwkv6-3b")
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, KEY, 2, 64)

    cfg_c = dataclasses.replace(cfg, rwkv_chunked=True)
    model_c = get_model(cfg_c)
    l_scan = jax.jit(model.loss)(params, batch)
    l_chunk = jax.jit(model_c.loss)(params, batch)
    np.testing.assert_allclose(float(l_scan), float(l_chunk), rtol=2e-3)

    lg_s, _ = jax.jit(model.prefill)(params, batch)
    lg_c, _ = jax.jit(model_c.prefill)(params, batch)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_c),
                               rtol=5e-2, atol=5e-2)
    # gradients agree too (backward of the chunked form); atol absorbs
    # f32 accumulation-order noise on near-zero entries (CPU)
    g_s = jax.jit(jax.grad(model.loss))(params, batch)
    g_c = jax.jit(jax.grad(model_c.loss))(params, batch)
    for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_c)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=1e-3)
