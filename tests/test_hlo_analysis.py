"""Unit tests for the HLO cost analyzer (trip counts, aliasing rules)."""

import textwrap

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as ha


def test_shape_bytes():
    assert ha._shape_bytes("f32[4,8]") == 128
    assert ha._shape_bytes("bf16[10]") == 20
    assert ha._shape_bytes("(f32[2], s32[3])") == 20
    assert ha._shape_bytes("pred[]") == 1


def test_collective_bytes_trip_weighted():
    hlo = textwrap.dedent("""\
        HloModule m

        %cond (arg: (s32[], f32[64])) -> pred[] {
          %arg = (s32[], f32[64]) parameter(0)
          %c = s32[] constant(5)
          %i = s32[] get-tuple-element(%arg), index=0
          ROOT %cmp = pred[] compare(%i, %c), direction=LT
        }

        %body (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
          %arg = (s32[], f32[64]) parameter(0)
          %x = f32[64]{0} get-tuple-element(%arg), index=1
          %ar = f32[64]{0} all-reduce(%x), to_apply=%add
          %i2 = s32[] get-tuple-element(%arg), index=0
          ROOT %t = (s32[], f32[64]) tuple(%i2, %ar)
        }

        ENTRY %main (p: f32[64]) -> f32[64] {
          %p = f32[64]{0} parameter(0)
          %ag = f32[128]{0} all-gather(%p), dimensions={0}
          %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
          ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
        }
        """)
    coll = ha.collective_bytes(hlo)
    # all-reduce inside the x5 loop: 64*4*5; all-gather once: 128*4
    assert coll["all-reduce"] == 64 * 4 * 5
    assert coll["all-gather"] == 128 * 4


def test_weighted_costs_dus_counts_slice_not_buffer():
    hlo = textwrap.dedent("""\
        HloModule m

        ENTRY %main (p: f32[1024,64]) -> f32[1024,64] {
          %p = f32[1024,64]{1,0} parameter(0)
          %u = f32[1,64]{1,0} parameter(1)
          %z = s32[] constant(0)
          ROOT %dus = f32[1024,64]{1,0} dynamic-update-slice(%p, %u, %z, %z)
        }
        """)
    wc = ha.weighted_costs(hlo)
    # 2x the 1x64 update, NOT the 1024x64 buffer
    assert wc["hbm_bytes"] == 2 * 64 * 4


def test_weighted_costs_dynamic_slice_counts_result():
    hlo = textwrap.dedent("""\
        HloModule m

        ENTRY %main (p: f32[1024,64]) -> f32[2,64] {
          %p = f32[1024,64]{1,0} parameter(0)
          %z = s32[] constant(0)
          ROOT %ds = f32[2,64]{1,0} dynamic-slice(%p, %z, %z), dynamic_slice_sizes={2,64}
        }
        """)
    wc = ha.weighted_costs(hlo)
    assert wc["hbm_bytes"] == 2 * 2 * 64 * 4


def test_weighted_flops_on_real_nested_scan():
    """Nested scans (layers x microbatches) multiply correctly."""

    @jax.jit
    def f(x, ws):
        def outer(x, _):
            def inner(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, ws)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=3)
        return x

    m = 32
    comp = f.lower(
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((4, m, m), jnp.float32),
    ).compile()
    wc = ha.weighted_costs(comp.as_text())
    assert wc["flops"] == 2.0 * m * m * m * 4 * 3


def test_multipliers_handle_missing_trip_count():
    # a while with no integer constant in the cond defaults to x1
    comps = {"main": "while(%x), condition=%c, body=%b", "c": "", "b": ""}
    mult = ha._multipliers(comps)
    assert mult["b"] == 1
