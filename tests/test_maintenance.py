"""Fleet maintenance plane: streaming, lease reclamation, the scheduler.

Contracts under test:

* ``fleet.stream_tenants`` on a single-tenant mask ≡ ``chain.stream`` on
  the equivalent chain (same shared ``merge_tables`` core, so metadata and
  reads agree field-for-field, ptr space excepted);
* streamed/compacted tenants return whole quanta to the allocator free
  list, and freed quanta can be re-leased by *other* tenants without ever
  aliasing two tenants' rows (property-tested);
* ``overflow`` clears only when rows were actually reclaimed, and
  ``snap_dropped`` clears iff streaming made room below ``max_chain``;
* the ``MaintenanceScheduler`` drains the backlog at most K tenants per
  tick and leaves serving results unchanged.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet, store
from repro.core.invariants import check_fleet_invariants
from repro.core.scheduler import MaintenanceScheduler

N_PAGES, PAGE, MAXC = 64, 4, 8
METHODS = ("vanilla", "direct", "auto")


def make_fleet(n_tenants, scalable, *, pool_capacity=2048, lease_quantum=8,
               max_chain=MAXC):
    spec = fleet.FleetSpec(
        n_tenants=n_tenants, n_pages=N_PAGES, page_size=PAGE,
        max_chain=max_chain, pool_capacity=pool_capacity,
        lease_quantum=lease_quantum, l2_per_table=32,
    )
    return fleet.create(spec, scalable=jnp.asarray(scalable, bool))


def make_chains(scalable, *, pool_capacity=2048, max_chain=MAXC):
    return [
        store.create(n_pages=N_PAGES, page_size=PAGE, max_chain=max_chain,
                     pool_capacity=pool_capacity, scalable=bool(s),
                     l2_per_table=32)
        for s in scalable
    ]


def grow(fl, chains, layers, *, writes=8, seed=0):
    """Write+snapshot ``layers`` times on the fleet and mirrored chains."""
    t = len(chains)
    rng = np.random.default_rng(seed)
    for layer in range(layers):
        ids = np.stack([rng.choice(N_PAGES, writes, replace=False)
                        for _ in range(t)]).astype(np.int32)
        data = rng.standard_normal((t, writes, PAGE)).astype(np.float32)
        fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data))
        chains = [store.write(c, jnp.asarray(ids[i]), jnp.asarray(data[i]))
                  for i, c in enumerate(chains)]
        if layer < layers - 1:
            fl = fleet.snapshot(fl)
            chains = [store.snapshot(c) for c in chains]
    return fl, chains


def assert_equivalent(fl, chains):
    """Fleet ≡ mirrored chains on every resolver (ptr space excepted)."""
    t = len(chains)
    np.testing.assert_array_equal(
        np.asarray(fl.length), [int(c.length) for c in chains])
    np.testing.assert_array_equal(
        np.asarray(fl.snap_dropped), [bool(c.snap_dropped) for c in chains])
    ids = jnp.broadcast_to(jnp.arange(N_PAGES, dtype=jnp.int32)[None],
                           (t, N_PAGES))
    for method in METHODS:
        fr = fleet.get_resolver(method)(fl, ids)
        fdata, _ = fleet.read(fl, ids, method=method)
        for i, ch in enumerate(chains):
            cdata, cr = store.read(ch, jnp.arange(N_PAGES, dtype=jnp.int32),
                                   method=method)
            for field in ("owner", "found", "zero", "lookups"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(fr, field)[i]),
                    np.asarray(getattr(cr, field)),
                    err_msg=f"{method} tenant {i} field {field}",
                )
            np.testing.assert_allclose(
                np.asarray(fdata[i]), np.asarray(cdata), rtol=1e-6,
                err_msg=f"{method} tenant {i} data",
            )


# The lease-discipline checks were promoted into the shared invariant
# suite (repro.core.invariants) so the scenario harness and migration
# verification run the same implementation this file grew them as.
check_lease_invariants = check_fleet_invariants


# -- stream_tenants ≡ chain.stream -------------------------------------------


@pytest.mark.parametrize("scalable", [True, False])
@pytest.mark.parametrize("merge_upto", [0, 1, 3])
def test_stream_single_tenant_mask_equals_chain_stream(scalable, merge_upto):
    fl, chains = grow(make_fleet(3, [scalable] * 3),
                      make_chains([scalable] * 3), layers=5, seed=1)
    mask = np.asarray([False, True, False])
    fl2 = fleet.stream_tenants(fl, mask, merge_upto)
    chains2 = list(chains)
    chains2[1] = store.stream(chains[1], merge_upto, copy_data=False)
    assert_equivalent(fl2, chains2)
    check_lease_invariants(fl2)
    # untouched tenants kept their full chains
    np.testing.assert_array_equal(
        np.asarray(fl2.length), [5, 5 - merge_upto, 5])


def test_stream_skips_tenants_it_cannot_merge():
    """A background job must tolerate racing chain growth: tenants whose
    merge_upto is not strictly below the active volume are skipped, where
    chain.stream (a foreground op) raises."""
    fl, chains = grow(make_fleet(2, [True, True]),
                      make_chains([True, True]), layers=3, seed=2)
    fl = fleet.snapshot(fl, jnp.asarray([True, False]))     # lengths 4, 3
    chains[0] = store.snapshot(chains[0])
    fl2 = fleet.stream_tenants(fl, True, 2)     # valid for t0 only
    chains2 = [store.stream(chains[0], 2, copy_data=False), chains[1]]
    assert_equivalent(fl2, chains2)
    with pytest.raises(ValueError):
        store.stream(chains[1], 2)


def test_stream_reclaims_quanta_to_free_list():
    """Full streaming of heavily-overwritten chains shrinks every lease
    field and returns quanta to the allocator."""
    fl = make_fleet(4, [True] * 4, pool_capacity=1024)
    ids = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (4, 8))
    for layer in range(5):      # same 8 pages overwritten 5x: 4/5 garbage
        fl = fleet.write(fl, ids, jnp.full((4, 8, PAGE), float(layer + 1)))
        if layer < 4:
            fl = fleet.snapshot(fl)
    before = np.asarray(fleet.materialize(fl))
    stats0 = fleet.fleet_stats(fl)
    assert np.asarray(fl.alloc_count).tolist() == [40] * 4
    fl = fleet.stream_tenants(fl, True, np.asarray(fl.length) - 2)
    np.testing.assert_allclose(np.asarray(fleet.materialize(fl)), before)
    # live rows per tenant: 8 in the merged base (layer-4 values) + 8 the
    # active volume owns (layer-5 values); the other 24 were reclaimed
    assert np.asarray(fl.alloc_count).tolist() == [16] * 4
    assert np.asarray(fl.lease_count).tolist() == [2] * 4
    stats1 = fleet.fleet_stats(fl)
    assert stats1["quanta_free"] == stats0["quanta_free"] + 3 * 4
    check_lease_invariants(fl)
    # freed quanta are re-leasable: another round of writes succeeds
    fl = fleet.write(fl, ids + 16, jnp.full((4, 8, PAGE), 9.0))
    assert not np.asarray(fl.overflow).any()
    check_lease_invariants(fl)


def test_compact_reclaims_cow_garbage_and_overflow_clears_iff_reclaimed():
    fl = make_fleet(2, [True, True], pool_capacity=48, lease_quantum=8)
    ids = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    # each write allocates 8 fresh rows; 3 rounds = 24 rows per tenant,
    # 16 of them superseded COW garbage (no snapshots, same pages)
    for v in (1.0, 2.0, 3.0):
        fl = fleet.write(fl, ids, jnp.full((2, 8, PAGE), v))
    # all 6 quanta leased; the next round has nowhere to go
    fl = fleet.write(fl, ids + 8, jnp.full((2, 8, PAGE), 4.0))
    over = np.asarray(fl.overflow)
    assert over.sum() == 2          # pool is dry for both tenants
    before = np.asarray(fleet.materialize(fl))
    fl2 = fleet.compact(fl)
    np.testing.assert_allclose(np.asarray(fleet.materialize(fl2)), before)
    # COW garbage reclaimed for both tenants -> overflow cleared
    assert not np.asarray(fl2.overflow).any()
    assert fleet.fleet_stats(fl2)["quanta_free"] > 0
    check_lease_invariants(fl2)
    # compaction converged: a second pass reclaims nothing further
    fl3 = fleet.compact(fl2)
    np.testing.assert_array_equal(np.asarray(fl3.alloc_count),
                                  np.asarray(fl2.alloc_count))
    np.testing.assert_array_equal(np.asarray(fl3.lease_count),
                                  np.asarray(fl2.lease_count))


def test_overflow_stays_latched_when_nothing_reclaimable():
    """All rows live -> compact reclaims nothing -> overflow must stay."""
    fl = make_fleet(1, [True], pool_capacity=8, lease_quantum=8)
    ids = jnp.arange(8, dtype=jnp.int32)[None]
    fl = fleet.write(fl, ids, jnp.ones((1, 8, PAGE)))       # fills the pool
    fl = fleet.write(fl, ids + 8, jnp.ones((1, 8, PAGE)))   # all dropped
    assert bool(fl.overflow[0])
    fl2 = fleet.compact(fl)
    assert bool(fl2.overflow[0])            # nothing was reclaimed
    assert int(fl2.alloc_count[0]) == 8


def test_snap_dropped_clears_iff_streaming_made_room():
    fl = make_fleet(1, [True], max_chain=3)
    ids = jnp.arange(4, dtype=jnp.int32)[None]
    fl = fleet.write(fl, ids, jnp.ones((1, 4, PAGE)))
    fl = fleet.snapshot(fleet.snapshot(fl))     # at max_chain
    fl = fleet.snapshot(fl)                     # dropped
    assert bool(fl.snap_dropped[0])
    still = fleet.stream_tenants(fl, True, 0)   # merges nothing away
    assert bool(still.snap_dropped[0])          # still at max_chain
    made_room = fleet.stream_tenants(fl, True, 1)
    assert not bool(made_room.snap_dropped[0])
    assert int(made_room.length[0]) == 2


# -- lease free -> re-acquire cycles ------------------------------------------


def test_reclaimed_quanta_reacquired_without_aliasing():
    """Quanta freed by one tenant's stream are re-leased to others; data
    never crosses tenants."""
    fl = make_fleet(2, [True, True], pool_capacity=48, lease_quantum=8)
    ids8 = jnp.arange(8, dtype=jnp.int32)
    # tenant 0 burns 4 quanta on COW garbage (t1 idle)
    for layer in range(4):
        fl = fleet.write(fl, ids8[None].repeat(2, 0),
                         jnp.full((2, 8, PAGE), float(layer + 1)),
                         jnp.asarray([True, False]))
        if layer < 3:
            fl = fleet.snapshot(fl, jnp.asarray([True, False]))
    assert int(fl.lease_count[0]) == 4
    fl = fleet.stream_tenants(fl, jnp.asarray([True, False]),
                              np.asarray(fl.length) - 2)
    # 16 rows stay live (merged base + active volume) -> 2 of 4 quanta kept
    assert int(fl.lease_count[0]) == 2
    check_lease_invariants(fl)
    t0_data = np.asarray(fleet.materialize(fl))[0]
    # tenant 1 now claims all 4 remaining quanta -- two of them are the
    # ones tenant 0 just freed
    for i in range(4):
        fl = fleet.write(fl, jnp.stack([ids8, ids8 + 8 * i]),
                         jnp.full((2, 8, PAGE), 8.0 + i),
                         jnp.asarray([False, True]))
    assert not np.asarray(fl.overflow).any()
    assert int(fl.lease_count[1]) == 4
    check_lease_invariants(fl)
    np.testing.assert_allclose(np.asarray(fleet.materialize(fl))[0], t0_data)


def test_maintenance_property_random_ops():
    """Hypothesis: random write/snapshot/stream/compact interleavings keep
    fleet ≡ mirrored chains AND the lease invariants (no cross-tenant row
    aliasing through any free -> re-acquire cycle)."""
    pytest.importorskip("hypothesis",
                        reason="install extras: pip install -e .[test]")
    from hypothesis import given, settings, strategies as st

    n_t = 3
    op = st.tuples(
        st.sampled_from(["write", "snapshot", "stream", "compact"]),
        st.lists(st.booleans(), min_size=n_t, max_size=n_t),
        st.integers(0, 2**31 - 1),
    )

    @settings(deadline=None, max_examples=10)
    @given(st.lists(op, min_size=1, max_size=10),
           st.lists(st.booleans(), min_size=n_t, max_size=n_t))
    def run(ops, scalable):
        fl = make_fleet(n_t, scalable, pool_capacity=512)
        chains = make_chains(scalable, pool_capacity=512)
        for kind, mask, seed in ops:
            mask = np.asarray(mask, bool)
            if kind == "write":
                rng = np.random.default_rng(seed)
                ids = np.stack([rng.choice(N_PAGES, 6, replace=False)
                                for _ in range(n_t)]).astype(np.int32)
                data = rng.standard_normal((n_t, 6, PAGE)).astype(np.float32)
                fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data),
                                 jnp.asarray(mask))
                for i in range(n_t):
                    if mask[i]:
                        chains[i] = store.write(
                            chains[i], jnp.asarray(ids[i]),
                            jnp.asarray(data[i]))
            elif kind == "snapshot":
                fl = fleet.snapshot(fl, jnp.asarray(mask))
                for i in range(n_t):
                    if mask[i]:
                        chains[i] = store.snapshot(chains[i])
            elif kind == "stream":
                upto = seed % MAXC
                fl = fleet.stream_tenants(fl, mask, upto)
                for i in range(n_t):
                    if mask[i] and upto < int(chains[i].length) - 1:
                        chains[i] = store.stream(chains[i], upto,
                                                 copy_data=False)
            else:
                fl = fleet.compact(fl, mask)
            check_lease_invariants(fl)
        assert_equivalent(fl, chains)

    run()


# -- scheduler ----------------------------------------------------------------


def build_busy_fleet(n_tenants=6, layers=5, seed=3):
    fl = make_fleet(n_tenants, [True] * n_tenants, pool_capacity=4096)
    rng = np.random.default_rng(seed)
    for layer in range(layers):
        ids = np.stack([rng.choice(N_PAGES, 8, replace=False)
                        for _ in range(n_tenants)]).astype(np.int32)
        fl = fleet.write(fl, jnp.asarray(ids),
                         jnp.asarray(rng.standard_normal(
                             (n_tenants, 8, PAGE)).astype(np.float32)))
        if layer < layers - 1:
            fl = fleet.snapshot(fl)
    return fl


def test_scheduler_budget_and_drain():
    fl = build_busy_fleet()
    before = np.asarray(fleet.materialize(fl))
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=2)
    assert len(sched.candidates()) == 6
    report = sched.tick()
    assert len(report["streamed"]) == 2         # budget respected
    assert report["backlog"] == 4
    ticks = sched.drain()
    assert ticks == 2                           # 4 left / 2 per tick
    assert sched.tenants_streamed == 6
    assert np.asarray(sched.fleet.length).tolist() == [2] * 6
    np.testing.assert_allclose(
        np.asarray(fleet.materialize(sched.fleet)), before, rtol=1e-6)
    check_lease_invariants(sched.fleet)
    assert sched.stats()["quanta_reclaimed"] > 0
    # a drained fleet schedules no further work
    assert sched.candidates() == []


def test_scheduler_prefers_longest_chains():
    fl = build_busy_fleet(n_tenants=4, layers=3)
    fl = fleet.snapshot(fl, jnp.asarray([False, True, False, False]))
    fl = fleet.write(fl, jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32)[None],
                                          (4, 4)), jnp.ones((4, 4, PAGE)))
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=1)
    assert sched.candidates()[0] == 1           # the length-4 tenant first
    sched.tick()
    assert int(sched.fleet.length[1]) == 2


def test_scheduler_compacts_wedged_tenants():
    """Streaming alone cannot clear an overflow when the chain is short;
    the scheduler falls back to a fleet-wide compact."""
    fl = make_fleet(1, [True], pool_capacity=24, lease_quantum=8)
    ids = jnp.arange(8, dtype=jnp.int32)[None]
    for v in (1.0, 2.0, 3.0):       # 24 rows, 16 of them COW garbage
        fl = fleet.write(fl, ids, jnp.full((1, 8, PAGE), v))
    fl = fleet.write(fl, ids, jnp.full((1, 8, PAGE), 4.0))  # overflows
    assert bool(fl.overflow[0])
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=1)
    # a length-1 tenant cannot stream, but the compact fallback can help
    # it — the backlog (what drain() polls) must see that work
    assert sched.candidates() == []
    assert sched.backlog() == 1
    report = sched.tick()
    assert report["compacted"]
    assert not np.asarray(sched.fleet.overflow).any()
    # the write that was dropped now fits
    sched.fleet = fleet.write(sched.fleet, ids,
                              jnp.full((1, 8, PAGE), 4.0))
    assert not np.asarray(sched.fleet.overflow).any()
    np.testing.assert_allclose(
        np.asarray(fleet.materialize(sched.fleet))[0, :8], 4.0)


def test_scheduler_parks_wedged_tenants_instead_of_spinning():
    """A tenant whose overflow nothing can clear (all rows live) must not
    trigger a full-fleet compact on every tick, and must not wedge
    drain(): it is parked until its occupancy changes."""
    fl = make_fleet(1, [True], pool_capacity=8, lease_quantum=8)
    ids = jnp.arange(8, dtype=jnp.int32)[None]
    fl = fleet.write(fl, ids, jnp.ones((1, 8, PAGE)))       # pool full, live
    fl = fleet.write(fl, ids + 8, jnp.ones((1, 8, PAGE)))   # dropped
    fl = fleet.snapshot(fl)     # length 2: the tenant is streamable
    assert bool(fl.overflow[0])
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=1)
    first = sched.tick()
    assert first["compacted"]                   # it tried once
    assert bool(sched.fleet.overflow[0])        # ...and couldn't help
    assert sched.drain(max_ticks=10) == 0       # parked, not spinning
    second = sched.tick()
    assert not second["compacted"] and second["streamed"] == []
    # occupancy change (a snapshot) un-parks the tenant
    sched.fleet = fleet.snapshot(sched.fleet)
    assert sched.candidates() == [0]


def test_scheduler_converges_at_threshold_two():
    """stream_chain_threshold=2 (the benchmark's setting) must still
    converge: a length-2 chain is picked once, its no-op stream makes no
    progress, and it is parked — not re-streamed and repacked forever."""
    fl = build_busy_fleet()
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=2,
                                 stream_chain_threshold=2)
    sched.drain(max_ticks=20)           # raises if the backlog never empties
    assert np.asarray(sched.fleet.length).tolist() == [2] * 6
    # ticking a drained queue reports no work and touches nothing
    streamed_before = sched.tenants_streamed
    rep = sched.tick()
    assert rep["streamed"] == [] and not rep["compacted"]
    assert sched.tenants_streamed == streamed_before


def test_scheduler_parks_unhelpable_overflow_without_compaction():
    """With compact_on_overflow=False, an overflowed tenant streaming
    cannot help must still be parked after one futile attempt."""
    fl = make_fleet(1, [True], pool_capacity=8, lease_quantum=8)
    ids = jnp.arange(8, dtype=jnp.int32)[None]
    fl = fleet.write(fl, ids, jnp.ones((1, 8, PAGE)))       # pool full, live
    fl = fleet.write(fl, ids + 8, jnp.ones((1, 8, PAGE)))   # dropped
    fl = fleet.snapshot(fl)
    sched = MaintenanceScheduler(fl, compact_on_overflow=False)
    first = sched.tick()
    assert first["streamed"] == [0] and not first["compacted"]
    assert bool(sched.fleet.overflow[0])
    assert sched.drain(max_ticks=5) == 0    # parked, queue reads empty


def test_resolves_unperturbed_mid_maintenance():
    """Serving reads interleaved with scheduler ticks always see the same
    data as before maintenance started (the amortized-streaming analogue
    of the paper's §6.4 consistency requirement)."""
    fl = build_busy_fleet()
    before = np.asarray(fleet.materialize(fl))
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=1)
    seen_lengths = set()
    for _ in range(10):
        if sched.candidates():
            sched.tick()
        np.testing.assert_allclose(
            np.asarray(fleet.materialize(sched.fleet)), before, rtol=1e-6)
        seen_lengths.add(tuple(np.asarray(sched.fleet.length).tolist()))
    assert len(seen_lengths) > 1    # maintenance really ran incrementally


def _regrow(fl, tenants, *, layers, seed):
    """Write+snapshot the given tenants back up to ``layers`` files."""
    n_t = fl.spec.n_tenants
    rng = np.random.default_rng(seed)
    mask = np.zeros(n_t, bool)
    mask[tenants] = True
    while int(np.max(np.asarray(fl.length)[tenants])) < layers:
        ids = np.stack([rng.choice(N_PAGES, 4, replace=False)
                        for _ in range(n_t)]).astype(np.int32)
        fl = fleet.write(fl, jnp.asarray(ids),
                         jnp.asarray(rng.standard_normal(
                             (n_t, 4, PAGE)).astype(np.float32)),
                         mask=jnp.asarray(mask))
        fl = fleet.snapshot(fl, jnp.asarray(mask))
    return fl


def test_scheduler_aging_prevents_starvation():
    """Starvation guard: a modest chain behind heavier tenants that keep
    regrowing must still get streamed — passed-over candidates age into
    priority. With ``aging_weight=0`` the same workload starves it."""
    def run(aging_weight):
        fl = build_busy_fleet(n_tenants=4, layers=4, seed=5)
        # tenant 0 stays modest (length 4); 1..3 are deeper (length 7)
        fl = _regrow(fl, [1, 2, 3], layers=7, seed=6)
        sched = MaintenanceScheduler(fl, max_tenants_per_tick=1,
                                     aging_weight=aging_weight)
        picked = []
        for tick in range(12):
            rep = sched.tick()
            picked += rep["streamed"]
            if 0 in picked:
                break
            # the heavy tenants immediately regrow: the churn that would
            # starve tenant 0 under pure occupancy ranking
            heavy = [t for t in rep["streamed"] if t != 0]
            if heavy:
                sched.fleet = _regrow(sched.fleet, heavy, layers=7,
                                      seed=7 + tick)
        return picked, sched

    starved, _ = run(aging_weight=0)
    assert 0 not in starved                     # pure occupancy: starves
    picked, sched = run(aging_weight=1)
    assert 0 in picked                          # aging: eventually served
    assert int(sched.fleet.length[0]) == 2
    assert sched.stats()["max_wait"] >= 0
