"""ChainFleet: batched multi-tenant ops ≡ a python loop over single chains.

The fleet layer's contract is that, tenant by tenant, every batched
operation (resolve_{vanilla,direct,auto}, write, snapshot, read) behaves
exactly like the corresponding single-``Chain`` operation — including
mixed scalable/vanilla fleets and pool-lease exhaustion. These tests
mirror scripted (and, with hypothesis, random) op sequences onto both
representations and compare them field-for-field. Pool row *pointers* are
the one legitimate difference (shared leased pool vs private linear
pools), so data equality is checked through reads, not ptrs.

Every equivalence check runs over all resolver methods — the vmapped jnp
gather ("vanilla"/"gather"/"direct"/"auto") *and* the stacked Pallas
kernels ("pallas_vanilla"/"pallas_direct", interpret mode on CPU) — each
pinned against the same single-chain jnp oracle, so the kernel and gather
implementations cannot drift apart.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet, store

#: fleet resolver method → the single-chain oracle method it must match
METHODS = {
    "vanilla": "vanilla",
    "gather": "vanilla",            # alias: the vmapped-jnp implementation
    "direct": "direct",
    "auto": "auto",
    "pallas_vanilla": "vanilla",    # stacked kernel, walk semantics
    "pallas_direct": "direct",      # stacked kernel, direct semantics
}
N_PAGES, PAGE, MAXC = 64, 4, 8


def make_fleet(n_tenants, scalable, *, pool_capacity=2048, lease_quantum=32,
               max_chain=MAXC):
    spec = fleet.FleetSpec(
        n_tenants=n_tenants, n_pages=N_PAGES, page_size=PAGE,
        max_chain=max_chain, pool_capacity=pool_capacity,
        lease_quantum=lease_quantum, l2_per_table=32,
    )
    return fleet.create(spec, scalable=jnp.asarray(scalable, bool))


def make_chains(scalable, *, pool_capacity=2048, max_chain=MAXC):
    return [
        store.create(n_pages=N_PAGES, page_size=PAGE, max_chain=max_chain,
                     pool_capacity=pool_capacity, scalable=bool(s),
                     l2_per_table=32)
        for s in scalable
    ]


def apply_ops(ops, scalable):
    """Run (kind, mask, seed) ops on a fleet and mirrored single chains."""
    t = len(scalable)
    fl = make_fleet(t, scalable)
    chains = make_chains(scalable)
    for kind, mask, seed in ops:
        mask = np.asarray(mask, bool)
        if kind == "write":
            rng = np.random.default_rng(seed)
            ids = np.stack([rng.choice(N_PAGES, 6, replace=False)
                            for _ in range(t)]).astype(np.int32)
            data = rng.standard_normal((t, 6, PAGE)).astype(np.float32)
            fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data),
                             jnp.asarray(mask))
            for i in range(t):
                if mask[i]:
                    chains[i] = store.write(chains[i], jnp.asarray(ids[i]),
                                            jnp.asarray(data[i]))
        else:
            # no length filter: both representations cap at max_chain and
            # flag overflow, so the mirror stays exact even past the cap
            fl = fleet.snapshot(fl, jnp.asarray(mask))
            for i in range(t):
                if mask[i]:
                    chains[i] = store.snapshot(chains[i])
    return fl, chains


def assert_equivalent(fl, chains):
    t = len(chains)
    np.testing.assert_array_equal(
        np.asarray(fl.length), [int(c.length) for c in chains])
    ids = jnp.broadcast_to(jnp.arange(N_PAGES, dtype=jnp.int32)[None],
                           (t, N_PAGES))
    for method, oracle in METHODS.items():
        fr = fleet.get_resolver(method)(fl, ids)
        fdata, _ = fleet.read(fl, ids, method=method)
        for i, ch in enumerate(chains):
            cdata, cr = store.read(ch, jnp.arange(N_PAGES, dtype=jnp.int32),
                                   method=oracle)
            for field in ("owner", "found", "zero", "lookups"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(fr, field)[i]),
                    np.asarray(getattr(cr, field)),
                    err_msg=f"{method} tenant {i} field {field}",
                )
            np.testing.assert_allclose(
                np.asarray(fdata[i]), np.asarray(cdata), rtol=1e-6,
                err_msg=f"{method} tenant {i} data",
            )


def test_scripted_mixed_fleet_equals_loop():
    """Masked writes/snapshots on a mixed scalable/vanilla fleet."""
    scalable = [True, False, True, False, True]
    ops = [
        ("write", [1, 1, 1, 1, 1], 0),
        ("snapshot", [1, 1, 0, 1, 1], None),
        ("write", [1, 0, 1, 1, 0], 1),
        ("snapshot", [0, 1, 1, 0, 1], None),
        ("write", [1, 1, 0, 0, 1], 2),
        ("snapshot", [1, 1, 1, 1, 1], None),
        ("write", [1, 1, 1, 1, 1], 3),
    ]
    fl, chains = apply_ops(ops, scalable)
    assert_equivalent(fl, chains)
    assert not bool(jnp.any(fl.overflow))


def test_vanilla_tenants_walk_scalable_go_direct():
    """Fleet-granularity Eq. 1: per-tenant lookup cost depends on the
    tenant's own format, within one batched resolve."""
    scalable = [True, False]
    ops = [("write", [1, 1], 0)] + [("snapshot", [1, 1], None)] * 4
    fl, chains = apply_ops(ops, scalable)
    ids = jnp.broadcast_to(jnp.arange(N_PAGES, dtype=jnp.int32)[None], (2, N_PAGES))
    res = fleet.resolve_auto(fl, ids)
    found = np.asarray(res.found)
    lookups = np.asarray(res.lookups)
    assert np.all(lookups[0][found[0]] == 1)        # scalable: O(1)
    assert np.all(lookups[1][found[1]] == 5)        # vanilla: walks 5 layers
    assert_equivalent(fl, chains)


def test_pallas_methods_ragged_and_inactive_tenants():
    """Kernel resolvers over a fleet with ragged chain lengths and an
    inactive tenant (never written, length 1 — its direct kernel stages
    an empty active volume and its walk kernel must find nothing)."""
    scalable = [True, False, True, True]
    ops = [
        ("write", [1, 1, 1, 0], 0),
        ("snapshot", [1, 0, 1, 0], None),
        ("write", [1, 0, 1, 0], 1),
        ("snapshot", [1, 1, 0, 0], None),
        ("write", [1, 1, 0, 0], 2),
    ]
    fl, chains = apply_ops(ops, scalable)
    assert np.asarray(fl.length).tolist() == [3, 2, 2, 1]
    assert_equivalent(fl, chains)
    # the untouched tenant resolves to nothing on every kernel path
    ids = jnp.broadcast_to(jnp.arange(N_PAGES, dtype=jnp.int32)[None], (4, N_PAGES))
    for method in ("pallas_vanilla", "pallas_direct"):
        res = fleet.get_resolver(method)(fl, ids)
        assert not np.asarray(res.found[3]).any()


def test_auto_uses_kernels_on_aligned_layout():
    """n_pages % 128 == 0 qualifies the layout: method="auto" resolves
    through the stacked kernels, bit-identical to the vmapped jnp auto."""
    import jax

    from repro.core import resolve as resolve_lib

    spec = fleet.FleetSpec(
        n_tenants=2, n_pages=128, page_size=PAGE, max_chain=4,
        pool_capacity=256, lease_quantum=32, l2_per_table=32,
    )
    assert fleet._kernel_layout_ok(spec)
    fl = fleet.create(spec, scalable=jnp.asarray([True, False]))
    ids8 = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    fl = fleet.write(fl, ids8, jnp.ones((2, 8, PAGE)))
    fl = fleet.snapshot(fl)
    fl = fleet.write(fl, 8 + ids8, 2.0 * jnp.ones((2, 8, PAGE)))
    ids = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32)[None], (2, 128))
    got = fleet.resolve_auto(fl, ids)
    want = jax.vmap(resolve_lib.get_table_resolver("auto"))(
        fl.l2, fl.length, ids)
    for field in got._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=f"auto field {field}")
    data, res = fleet.read(fl, ids, method="auto")   # kernel gather path
    np.testing.assert_allclose(
        np.asarray(data),
        np.asarray(store.gather_pages(fl.pool, res)), rtol=1e-6)


def test_lease_exhaustion_isolated_per_tenant():
    """A tenant running the shared pool dry flags only itself; other
    tenants' leases and data are untouched and stay equivalent."""
    fl = make_fleet(3, [True, True, True], pool_capacity=32, lease_quantum=8)
    ids = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (3, 8))
    fl = fleet.write(fl, ids, jnp.full((3, 8, PAGE), 1.0))   # 3/4 quanta gone
    fl = fleet.write(fl, ids, jnp.full((3, 8, PAGE), 2.0))   # only one fits
    over = np.asarray(fl.overflow)
    assert over.sum() == 2                     # exactly one tenant won round 2
    winner = int(np.flatnonzero(~over)[0])
    data = np.asarray(fleet.materialize(fl))
    assert np.all(data[winner, :8] == 2.0)
    for t in range(3):
        if t != winner:
            # losers keep their round-1 data; dropped writes corrupt nothing
            assert np.all(data[t, :8] == 1.0)
    with pytest.raises(RuntimeError, match="pool exhausted"):
        fleet.check_pool_capacity(fl)
    # every quantum is leased, and the winner holds exactly two of them
    owner = np.asarray(fl.lease_owner)
    assert (owner >= 0).all()
    assert (owner == winner).sum() == 2
    assert np.asarray(fl.alloc_count)[winner] == 16


def test_single_tenant_fills_entire_pool_then_drops():
    """A tenant leasing every quantum (including the final lease-list slot)
    keeps all its data; writes past pool capacity are dropped — never
    aliased onto the final quantum's immutable rows — and flag overflow."""
    fl = make_fleet(1, [True], pool_capacity=32, lease_quantum=8)
    ids = jnp.arange(8, dtype=jnp.int32)[None]
    for i in range(4):                       # exactly fills all 4 quanta
        fl = fleet.write(fl, ids + 8 * i, jnp.full((1, 8, PAGE), float(i + 1)))
    assert not bool(fl.overflow[0])
    assert int(fl.alloc_count[0]) == 32
    assert np.asarray(fl.lease_index[0]).min() >= 0   # last slot stitched
    data = np.asarray(fleet.materialize(fl))[0]
    for i in range(4):
        assert np.all(data[8 * i:8 * (i + 1)] == i + 1)
    fl = fleet.write(fl, ids, jnp.full((1, 8, PAGE), 99.0))  # pool is full
    assert bool(fl.overflow[0])
    assert int(fl.alloc_count[0]) == 32
    after = np.asarray(fleet.materialize(fl))[0]
    np.testing.assert_array_equal(after, data)        # nothing corrupted


def test_one_batch_wanting_more_quanta_than_pool_flags_overflow():
    """A single write batch needing more quanta than the pool holds must
    still set overflow (the wanted-lease count can exceed n_quanta)."""
    fl = make_fleet(1, [True], pool_capacity=32, lease_quantum=8)
    ids = jnp.arange(33, dtype=jnp.int32)[None]          # wants 5 of 4 quanta
    fl = fleet.write(fl, ids, jnp.ones((1, 33, PAGE)))
    assert bool(fl.overflow[0])
    assert int(fl.alloc_count[0]) == 32                  # 32 rows landed
    data = np.asarray(fleet.materialize(fl))[0]
    assert np.all(data[:32] == 1.0) and np.all(data[32] == 0.0)


def test_l1_presence_bit_survives_mid_batch_exhaustion():
    """A valid and a dropped page sharing one L2 table: the table's L1
    presence bit must end up set regardless of scatter order."""
    fl = make_fleet(1, [True], pool_capacity=8, lease_quantum=8)
    ids = jnp.arange(12, dtype=jnp.int32)[None]          # all in L2 table 0
    fl = fleet.write(fl, ids, jnp.ones((1, 12, PAGE)))
    assert bool(fl.overflow[0])
    assert int(fl.l1[0, 0, 0]) == 1                      # bit set by valid rows
    res = fleet.resolve_direct(fl, ids)
    assert np.asarray(res.found[0]).tolist() == [True] * 8 + [False] * 4


def test_snapshot_mask_and_chain_cap():
    fl = make_fleet(2, [True, True], max_chain=3)
    ids = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32)[None], (2, 4))
    fl = fleet.write(fl, ids, jnp.ones((2, 4, PAGE)))
    fl = fleet.snapshot(fl, jnp.asarray([True, False]))
    assert np.asarray(fl.length).tolist() == [2, 1]
    fl = fleet.snapshot(fl)                       # both advance
    assert np.asarray(fl.length).tolist() == [3, 2]
    fl = fleet.snapshot(fl)                       # tenant 0 at max_chain
    assert np.asarray(fl.length).tolist() == [3, 3]
    assert np.asarray(fl.snap_dropped).tolist() == [True, False]
    assert not np.asarray(fl.overflow).any()      # pool flag is separate


def test_tenant_chain_view_matches_batched_paths():
    ops = [("write", [1, 1, 1], 0), ("snapshot", [1, 1, 1], None),
           ("write", [1, 1, 1], 1)]
    fl, _ = apply_ops(ops, [True, False, True])
    full = np.asarray(fleet.materialize(fl))
    for t in range(3):
        view = fleet.tenant_chain(fl, t)
        np.testing.assert_allclose(
            np.asarray(store.materialize(view)), full[t], rtol=1e-6)


def test_fleet_property_random_ops():
    """Hypothesis: arbitrary masked write/snapshot interleavings over a
    mixed fleet keep fleet ≡ looped single chains for all resolvers."""
    pytest.importorskip("hypothesis",
                        reason="install extras: pip install -e .[test]")
    from hypothesis import given, settings, strategies as st

    n_t = 4
    op = st.tuples(
        st.sampled_from(["write", "snapshot"]),
        st.lists(st.booleans(), min_size=n_t, max_size=n_t),
        st.integers(0, 2**31 - 1),
    )

    @settings(deadline=None, max_examples=10)
    @given(st.lists(op, min_size=1, max_size=8),
           st.lists(st.booleans(), min_size=n_t, max_size=n_t))
    def run(ops, scalable):
        fl, chains = apply_ops(ops, scalable)
        assert_equivalent(fl, chains)

    run()


def test_free_tenant_returns_whole_lease_set():
    """``free_tenant`` drops a tenant's entire lease set in one call: its
    quanta return to the free list, its chain resets, other tenants are
    untouched, and a re-lease of the freed quanta never aliases."""
    ops = [("write", [True, True, True], 0), ("snapshot", [True, True, True], 0),
           ("write", [True, True, True], 1), ("write", [True, True, True], 2)]
    fl, chains = apply_ops(ops, [True, False, True])
    stats0 = fleet.fleet_stats(fl)
    held = int(np.asarray(fl.lease_count)[1])
    assert held > 0

    fl2 = fleet.free_tenant(fl, 1)
    stats1 = fleet.fleet_stats(fl2)
    # the whole lease set came back at once
    assert stats1["quanta_free"] == stats0["quanta_free"] + held
    owner = np.asarray(fl2.lease_owner)
    assert not np.any(owner == 1)
    assert int(fl2.length[1]) == 1 and int(fl2.alloc_count[1]) == 0
    # the freed tenant reads as an empty disk; the others are untouched
    data = np.asarray(fleet.materialize(fl2))
    np.testing.assert_array_equal(data[1], 0.0)
    ref = np.asarray(fleet.materialize(fl))
    np.testing.assert_allclose(data[0], ref[0], rtol=1e-6)
    np.testing.assert_allclose(data[2], ref[2], rtol=1e-6)

    # a new occupant re-leases the freed quanta without aliasing others
    fl3 = fleet.attach_tenant(fl2, 1, scalable=True)
    ids = jnp.arange(8, dtype=jnp.int32)[None]
    fl3 = fleet.write(fl3, jnp.broadcast_to(ids, (3, 8)),
                      jnp.full((3, 8, PAGE), 7.0),
                      mask=jnp.asarray([False, True, False]))
    data3 = np.asarray(fleet.materialize(fl3))
    np.testing.assert_allclose(data3[1, :8], 7.0, rtol=1e-6)
    np.testing.assert_allclose(data3[0], ref[0], rtol=1e-6)
    np.testing.assert_allclose(data3[2], ref[2], rtol=1e-6)


def test_free_tenant_mask_and_noop():
    fl, _ = apply_ops([("write", [True, True], 0)], [True, True])
    assert fleet.free_tenant(fl, np.zeros(2, bool)) is fl
    fl2 = fleet.free_tenant(fl, np.asarray([True, True]))
    assert fleet.fleet_stats(fl2)["quanta_leased"] == 0
    np.testing.assert_array_equal(np.asarray(fl2.length), [1, 1])


def test_fork_tenant_resolves_like_source_until_divergence():
    """``fork_tenant``/``clone_tenant``: the serving plane's fork — the
    clone resolves bit-identically to the source, then diverges when the
    caller stamps its own entries."""
    from repro.core import format as fmt

    fl, _ = apply_ops(
        [("write", [True, False, False], 0),
         ("snapshot", [True, False, False], 0),
         ("write", [True, False, False], 1)],
        [False, False, False],
    )
    fl = fleet.fork_tenant(fl, 0, 2)
    assert int(fl.length[2]) == int(fl.length[0]) + 1
    ids = jnp.broadcast_to(jnp.arange(N_PAGES, dtype=jnp.int32)[None],
                           (3, N_PAGES))
    res = fleet.resolve_vanilla(fl, ids)
    np.testing.assert_array_equal(np.asarray(res.ptr[2]),
                                  np.asarray(res.ptr[0]))
    np.testing.assert_array_equal(np.asarray(res.found[2]),
                                  np.asarray(res.found[0]))
    # divergence: stamp one entry into the fork's active layer only
    ent = fmt.pack_entry(jnp.uint32(3), jnp.uint32(0), allocated=True,
                         bfi_valid=False)
    fl = fleet.stamp_entries(fl, [2], [int(fl.length[2]) - 1], [0], ent[None])
    res2 = fleet.resolve_vanilla(fl, ids)
    assert int(res2.ptr[2, 0]) == 3
    np.testing.assert_array_equal(np.asarray(res2.ptr[0]),
                                  np.asarray(res.ptr[0]))


def test_free_tenant_empty_id_list_is_noop():
    fl, _ = apply_ops([("write", [True, True], 0)], [True, True])
    out = fleet.free_tenant(fl, [])
    np.testing.assert_array_equal(np.asarray(out.lease_count),
                                  np.asarray(fl.lease_count))
