"""Incremental snapshot checkpointing: roundtrip, deltas, restart, reshard."""


import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from repro.checkpoint.snapstore_ckpt import SnapshotCheckpointer

KEY = jax.random.PRNGKey(0)


def make_state(scale=1.0):
    return dict(
        w=scale * jax.random.normal(KEY, (32, 16)),
        b=jnp.zeros((16,)),
        step=jnp.asarray(int(scale), jnp.int32),
        nested=dict(m=scale * jnp.ones((8, 8)), flag=jnp.asarray(3, jnp.int32)),
    )


def test_roundtrip_all_dtypes():
    state = make_state()
    ck = SnapshotCheckpointer(state, page_size=64)
    ck.save(state)
    got = ck.restore()
    for a, b in zip(jtu.tree_leaves(state), jtu.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_bf16_leaves_roundtrip():
    state = dict(p=jax.random.normal(KEY, (9, 7)).astype(jnp.bfloat16))
    ck = SnapshotCheckpointer(state, page_size=32)
    ck.save(state)
    got = ck.restore()
    np.testing.assert_array_equal(
        np.asarray(state["p"], np.float32), np.asarray(got["p"], np.float32)
    )


def test_delta_saves_write_only_dirty_pages():
    state = make_state()
    ck = SnapshotCheckpointer(state, page_size=64)
    s1 = ck.save(state)
    assert s1["pages_written"] > 0
    # identical state → zero dirty pages
    s2 = ck.save(state)
    assert s2["pages_written"] == 0
    # touch one leaf → far fewer pages than the first full save
    state2 = dict(state)
    state2["b"] = state["b"] + 1.0
    s3 = ck.save(state2)
    assert 0 < s3["pages_written"] < s1["pages_written"]
    got = ck.restore()
    np.testing.assert_allclose(np.asarray(got["b"]), np.asarray(state2["b"]))


def test_restore_vanilla_equals_direct_with_cost_gap():
    state = make_state()
    # scalable format (sQEMU) vs vanilla format (vQemu) checkpoint chains
    ck_s = SnapshotCheckpointer(state, page_size=64, scalable=True)
    ck_v = SnapshotCheckpointer(state, page_size=64, scalable=False)
    for i in range(8):
        state = jtu.tree_map(
            lambda x: x + 1 if x.dtype == jnp.float32 else x, state
        )
        ck_s.save(state)
        ck_v.save(state)
    a = ck_s.restore(method="direct")
    b = ck_v.restore(method="vanilla")
    for x, y in zip(jtu.tree_leaves(a), jtu.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # restore cost: O(1)/page direct vs O(chain)/page walk (Fig 17 claim)
    assert ck_s.resolve_cost("direct") < ck_v.resolve_cost("vanilla")


def test_streaming_policy_bounds_chain():
    state = make_state()
    ck = SnapshotCheckpointer(state, page_size=64, stream_threshold=6)
    for i in range(20):
        state["step"] = jnp.asarray(i, jnp.int32)
        ck.save(state)
    assert int(ck.chain.length) <= 7
    got = ck.restore()
    assert int(got["step"]) == 19


def test_save_load_dir_restart(tmp_path):
    state = make_state()
    ck = SnapshotCheckpointer(state, page_size=64)
    ck.save(state)
    state["step"] = jnp.asarray(42, jnp.int32)
    ck.save(state)
    ck.save_to_dir(str(tmp_path))

    ck2 = SnapshotCheckpointer(state, page_size=64)
    ck2.load_from_dir(str(tmp_path))
    got = ck2.restore()
    assert int(got["step"]) == 42


def test_elastic_reshard():
    """Save unsharded, restore onto a live mesh with real shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    state = dict(w=jax.random.normal(KEY, (8, 16)))
    ck = SnapshotCheckpointer(state, page_size=32)
    ck.save(state)
    mesh = make_host_mesh(data=1, model=1)
    shardings = dict(w=NamedSharding(mesh, P(None, None)))
    got = ck.restore(shardings=shardings)
    assert got["w"].sharding == shardings["w"]
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(state["w"]))


@pytest.mark.slow
def test_trainer_crash_restart_resumes_identically():
    """End-to-end fault tolerance: crash, restore, bit-identical losses."""
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig
    from repro.models import get_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = smoke_config("qwen2.5-3b")
    model = get_model(cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    tcfg = TrainerConfig(total_steps=9, ckpt_every=3, page_size=256)

    ref = Trainer(model, AdamWConfig(lr=1e-3), dcfg, tcfg, seed=0)
    ref.run()

    t = Trainer(model, AdamWConfig(lr=1e-3), dcfg, tcfg, seed=0)
    with pytest.raises(RuntimeError, match="simulated crash"):
        t.run(crash_after=5)
    # restart from the last checkpoint (step 3) and finish
    resumed_at = t.resume()
    assert resumed_at == 3
    t.run()
    np.testing.assert_allclose(t.losses[-1], ref.losses[-1], rtol=1e-5)


def test_async_save_overlaps_and_orders():
    state = make_state()
    ck = SnapshotCheckpointer(state, page_size=64)
    futs = []
    for i in range(4):
        state = dict(state)
        state["step"] = jnp.asarray(i, jnp.int32)
        futs.append(ck.save_async(state))
    stats = [f.result() for f in futs]
    assert [s["chain_length"] for s in stats] == [2, 3, 4, 5]
    got = ck.restore()
    assert int(got["step"]) == 3
