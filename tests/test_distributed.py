"""Sharding rules, HLO analysis, compression, cache simulator behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.launch import hlo_analysis
from repro.launch.mesh import make_abstract_mesh


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: rule resolution needs only axis names/sizes, so tests
    # exercise the production 16x16 geometry without 256 devices.
    return make_abstract_mesh((16, 16), ("data", "model"))


def test_rules_divisibility_guard(mesh):
    rules = sh.make_rules(mesh)
    # a dim not divisible by the axis size stays unsharded
    model_size = mesh.shape["model"]
    spec = rules.spec(("heads",), (model_size + 1,))
    assert spec == P(None)
    spec2 = rules.spec(("heads",), (model_size * 4,))
    assert spec2 == P("model")


def test_rules_duplicate_axis_dedup(mesh):
    rules = sh.make_rules(mesh)
    ms = mesh.shape["model"]
    spec = rules.spec(("kv_seq", "kv_heads"), (ms * 2, ms * 2))
    # both map to "model"; only the first may keep it
    assert spec[0] == "model" and spec[1] is None


def test_param_specs_name_rules(mesh):
    rules = sh.make_rules(mesh)
    params = dict(
        layers=dict(attn=dict(
            wq=jax.ShapeDtypeStruct((4, 64, 64), jnp.float32))),
        embed=jax.ShapeDtypeStruct((128, 64), jnp.float32),
        ln=jax.ShapeDtypeStruct((64,), jnp.float32),
    )
    specs = sh.param_specs(params, rules)
    assert specs["ln"] == P(None)
    assert len(specs["layers"]["attn"]["wq"]) == 3  # stacked rank respected


def test_weighted_costs_exact_on_known_scan():
    """flops of a scanned matmul == 2*M*N*K*trips exactly."""

    @jax.jit
    def f(a, b):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, a, b)
        return x

    m = n = k = 64
    trips = 7
    comp = f.lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((trips, k, n), jnp.float32),
    ).compile()
    wc = hlo_analysis.weighted_costs(comp.as_text())
    assert wc["flops"] == 2.0 * m * n * k * trips


def test_compressed_psum_error_feedback():
    """Error feedback: accumulated compressed transmissions converge to the
    true mean (the property that keeps SGD convergence intact)."""
    from repro.distributed import compression as comp

    x = jnp.asarray(np.random.default_rng(0).standard_normal(256) * 3,
                    jnp.float32)
    err = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for _ in range(50):
        y = x + err
        q, scale = comp.quantize_int8(y)
        deq = q.astype(jnp.float32) * scale
        err = y - deq
        total = total + deq
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(x),
                               atol=3e-3)
    # single-shot error is bounded by the quantization step
    q, scale = comp.quantize_int8(x)
    assert float(jnp.max(jnp.abs(x - q.astype(jnp.float32) * scale))) <= float(scale)


def test_compression_wire_bytes():
    from repro.distributed import compression as comp

    tree = dict(a=jnp.zeros((100,)), b=jnp.zeros((28,)))
    assert comp.wire_bytes(tree, compressed=False) == 512
    assert comp.wire_bytes(tree, compressed=True) == 128 + 8


@pytest.mark.slow
def test_cache_sim_vanilla_grows_unified_flat():
    """The paper's core low-level claim, on the simulator (Fig 13)."""
    from repro.core import cache, store

    def build(length, scalable):
        ch = store.create(n_pages=128, page_size=4, max_chain=32,
                          scalable=scalable, pool_capacity=4096)
        key = jax.random.PRNGKey(0)
        for i in range(length - 1):
            ids = jax.random.choice(jax.random.fold_in(key, i), 128, (16,),
                                    replace=False).astype(jnp.int32)
            ch = store.write(ch, ids, jnp.ones((16, 4)))
            ch = store.snapshot(ch)
        return ch

    reqs = jnp.arange(128, dtype=jnp.int32)
    v_short = cache.summarize(cache.simulate_vanilla(build(4, False), reqs, 8))
    v_long = cache.summarize(cache.simulate_vanilla(build(24, False), reqs, 8))
    u_short = cache.summarize(cache.simulate_unified(build(4, True), reqs, 8))
    u_long = cache.summarize(cache.simulate_unified(build(24, True), reqs, 8))
    # vanilla: unallocated-hit events grow with chain length
    assert v_long["hit_unallocated"] > 2 * max(v_short["hit_unallocated"], 1)
    # unified: probes stay one-per-request; unallocated events ~flat
    assert u_long["probes"] == u_short["probes"] == 128
    assert u_long["hit_unallocated"] <= u_short["hit_unallocated"] + 8


def test_cache_memory_model_fig12_shape():
    from repro.core.cache import cache_memory_bytes
    from repro.core.chain import ChainSpec

    spec = ChainSpec(n_pages=1024, page_size=16, max_chain=1024,
                     pool_capacity=2048)
    v = [cache_memory_bytes(spec, 64, n, unified=False) for n in (1, 500, 1000)]
    u = [cache_memory_bytes(spec, 64, n, unified=True) for n in (1, 500, 1000)]
    assert v[2] > 100 * v[0]            # vanilla grows linearly
    assert v[1] / u[1] > 10             # paper: 15.2x at length 500
    # the cache itself is chain-length independent; only the residual
    # per-snapshot driver structures grow (paper §6.2 observes the same)
    flat = [cache_memory_bytes(spec, 64, n, unified=True,
                               per_snapshot_overhead=0) for n in (1, 1000)]
    assert flat[0] == flat[1]
