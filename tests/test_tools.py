"""Coverage for the stdlib CI checkers: check_links anchor validation
and check_bench artifact-schema validation."""

import importlib.util
import json
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_links = _load("check_links")
check_bench = _load("check_bench")


# -------------------------------------------------------- check_links

def _md(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return check_links.main(["check_links.py", str(tmp_path)])


def test_valid_file_and_anchor_links_pass(tmp_path):
    assert _md(tmp_path, {
        "docs/a.md": "# My Title\n\n## Sub-Section two!\nbody\n",
        "docs/b.md": "[x](a.md) [y](a.md#my-title) "
                     "[z](a.md#sub-section-two) [w](#local)\n\n# Local\n",
    }) == 0


def test_broken_anchor_fails(tmp_path):
    assert _md(tmp_path, {
        "docs/a.md": "# Title\n",
        "docs/b.md": "[y](a.md#no-such-heading)\n",
    }) == 1


def test_broken_file_still_fails(tmp_path):
    assert _md(tmp_path, {"a.md": "[y](missing.md)\n"}) == 1


def test_duplicate_headings_get_github_suffixes(tmp_path):
    assert _md(tmp_path, {
        "a.md": "# Setup\n\n# Setup\n",
        "b.md": "[one](a.md#setup) [two](a.md#setup-1)\n",
    }) == 0


def test_headings_inside_code_fences_are_not_anchors(tmp_path):
    assert _md(tmp_path, {
        "a.md": "```\n# not a heading\n```\n# Real\n",
        "b.md": "[bad](a.md#not-a-heading)\n",
    }) == 1


def test_slugify_matches_github():
    assert check_links.slugify("My `Title` — v2.0!") == "my-title--v20"
    assert check_links.slugify("HBM ↔ host") == "hbm--host"


def test_repo_docs_links_are_valid():
    assert check_links.main(["check_links.py", str(REPO)]) == 0


# -------------------------------------------------------- check_bench

def _artifact(tmp_path, payload, name="BENCH_x.json"):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return check_bench.check_artifact(p)


TIERED_REC = {
    "mode": "tiered", "depth": 500, "tenants_live": 24, "pool_rows": 300,
    "page_size": 8, "worst_tick_ms": 1.0, "mean_tick_ms": 0.5, "ticks": 10,
    "rows_demoted": 100, "rows_promoted": 10, "host_rows": 90,
    "stw_demote_ms": 50.0, "promote_wave_ms": 2.0,
    "ratio_vs_baseline": 6.0, "verified": True,
}


def test_valid_tiering_artifact_passes(tmp_path):
    assert _artifact(tmp_path, {
        "benchmark": "tiering", "results": [TIERED_REC], "wave": 4,
    }) == []


def test_missing_required_key_fails(tmp_path):
    rec = {k: v for k, v in TIERED_REC.items() if k != "ratio_vs_baseline"}
    errs = _artifact(tmp_path, {"benchmark": "tiering", "results": [rec]})
    assert errs and "ratio_vs_baseline" in errs[0]


def test_unverified_cell_fails(tmp_path):
    rec = dict(TIERED_REC, verified=False)
    errs = _artifact(tmp_path, {"benchmark": "tiering", "results": [rec]})
    assert errs and "not bit-verified" in errs[0]


def test_nan_anywhere_fails(tmp_path):
    rec = dict(TIERED_REC, mean_tick_ms=float("nan"))
    errs = _artifact(tmp_path, {"benchmark": "tiering", "results": [rec]})
    assert errs and "non-finite" in errs[0]


def test_null_is_not_nan(tmp_path):
    # baseline cells legitimately carry null tick stats (schema: "null/0
    # for baseline")
    rec = dict(TIERED_REC, mode="baseline", worst_tick_ms=None,
               mean_tick_ms=None, ticks=0)
    rec.pop("promote_wave_ms")
    rec.pop("ratio_vs_baseline")
    assert _artifact(tmp_path, {
        "benchmark": "tiering", "results": [rec]}) == []


def test_fleet_sections_discriminate(tmp_path):
    good = {"section": "resolver", "tenants": 8, "chain": 500,
            "method": "pallas_direct", "format": "scalable",
            "resolve_us": 10.0, "mpages_s": 1.0, "mean_lookups": 1.0}
    assert _artifact(tmp_path, {
        "benchmark": "fleet", "results": [good]}) == []
    bad = dict(good)
    bad.pop("mean_lookups")
    errs = _artifact(tmp_path, {"benchmark": "fleet", "results": [bad]})
    assert errs and "mean_lookups" in errs[0]


def test_empty_results_fails(tmp_path):
    errs = _artifact(tmp_path, {"benchmark": "serve", "results": []})
    assert errs


def test_real_ci_artifact_if_present():
    p = REPO / "BENCH_tiering.json"
    if p.exists():
        assert check_bench.check_artifact(p) == []
