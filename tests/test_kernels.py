"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import format as fmt
from repro.kernels.chain_resolve import ref as cr_ref
from repro.kernels.chain_resolve.chain_resolve import (
    resolve_direct_fleet_pallas, resolve_direct_pallas,
    resolve_vanilla_fleet_pallas, resolve_vanilla_pallas)
from repro.kernels.cow_gather import ref as cg_ref
from repro.kernels.cow_gather.cow_gather import gather_fleet_pallas, gather_pallas
from repro.kernels.paged_attention import ops as pa_ops
from repro.kernels.paged_attention import ref as pa_ref
from repro.kernels.paged_attention.paged_attention import (
    fused_chain_attention_pallas, paged_attention_pallas)
from repro.kernels.stream_merge import ref as sm_ref
from repro.kernels.stream_merge.stream_merge import merge_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("c,n", [(1, 128), (4, 256), (16, 640), (64, 128)])
@pytest.mark.parametrize("density", [0.05, 0.5, 1.0])
def test_chain_resolve_vanilla_sweep(c, n, density):
    alloc = (jax.random.uniform(jax.random.fold_in(KEY, c * n), (c, n))
             < density).astype(jnp.uint32)
    ptrs = jax.random.randint(KEY, (c, n), 0, 10_000).astype(jnp.uint32)
    for length in {1, c // 2 or 1, c}:
        o1, p1 = cr_ref.resolve_vanilla_ref(alloc, ptrs, length)
        o2, p2 = resolve_vanilla_pallas(alloc, ptrs, length, interpret=True)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("n", [128, 384, 1024])
def test_chain_resolve_direct_sweep(n):
    alloc = (jax.random.uniform(KEY, (n,)) < 0.6).astype(jnp.uint32)
    bfi = jax.random.randint(KEY, (n,), 0, 500).astype(jnp.uint32)
    ptrs = jax.random.randint(KEY, (n,), 0, 10_000).astype(jnp.uint32)
    o1, p1 = cr_ref.resolve_direct_ref(alloc, bfi, ptrs)
    o2, p2 = resolve_direct_pallas(alloc, bfi, ptrs, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def _packed_fleet_words(key, t, c, p, density):
    """Random stacked L2 word pairs in the real ``core.format`` layout."""
    ks = [jax.random.fold_in(key, i) for i in range(5)]
    entries = fmt.pack_entry(
        jax.random.randint(ks[0], (t, c, p), 0, 10_000).astype(jnp.uint32),
        jax.random.randint(ks[1], (t, c, p), 0, c).astype(jnp.uint32),
        allocated=jax.random.uniform(ks[2], (t, c, p)) < density,
        bfi_valid=jax.random.uniform(ks[3], (t, c, p)) < 0.7,
        zero=jax.random.uniform(ks[4], (t, c, p)) < 0.1,
    )
    return entries[..., 0], entries[..., 1]


@pytest.mark.parametrize("t,c,p", [(1, 1, 128), (3, 7, 256), (5, 16, 640),
                                   (2, 64, 128)])
@pytest.mark.parametrize("density", [0.05, 0.5, 1.0])
def test_chain_resolve_vanilla_fleet_sweep(t, c, p, density):
    key = jax.random.fold_in(KEY, t * c * p)
    w0, _ = _packed_fleet_words(key, t, c, p, density)
    # ragged lengths, including the length-1 (nothing-below-active) tenant
    lengths = jax.random.randint(jax.random.fold_in(key, 9), (t,), 1, c + 1)
    o1, h1 = cr_ref.resolve_vanilla_fleet_ref(w0, lengths)
    o2, h2 = resolve_vanilla_fleet_pallas(w0, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


@pytest.mark.parametrize("t,c,p", [(1, 1, 128), (4, 9, 256), (3, 32, 640)])
def test_chain_resolve_direct_fleet_sweep(t, c, p):
    key = jax.random.fold_in(KEY, t * c * p + 1)
    w0, w1 = _packed_fleet_words(key, t, c, p, 0.6)
    lengths = jax.random.randint(jax.random.fold_in(key, 9), (t,), 1, c + 1)
    r1 = cr_ref.resolve_direct_fleet_ref(w0, w1, lengths)
    r2 = resolve_direct_fleet_pallas(w0, w1, lengths, interpret=True)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,page,t,b", [(16, 128, 2, 8), (64, 256, 5, 17)])
def test_cow_gather_fleet_sweep(dtype, rows, page, t, b):
    pool = jax.random.normal(KEY, (rows, page)).astype(dtype)
    idx = jax.random.randint(KEY, (t, b), 0, rows)
    found = jax.random.uniform(jax.random.fold_in(KEY, 1), (t, b)) < 0.8
    o1 = cg_ref.gather_fleet_ref(pool, idx, found)
    o2 = gather_fleet_pallas(pool, idx, found, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,page", [(16, 128), (64, 256), (200, 512)])
def test_cow_gather_sweep(dtype, rows, page):
    pool = jax.random.normal(KEY, (rows, page)).astype(dtype)
    b = min(rows, 32)
    idx = jax.random.randint(KEY, (b,), 0, rows)
    found = jax.random.uniform(jax.random.fold_in(KEY, 1), (b,)) < 0.8
    o1 = cg_ref.gather_ref(pool, idx, found)
    o2 = gather_pallas(pool, idx, found, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("h,hkv,d,bs,m", [
    (8, 2, 64, 16, 4),    # GQA 4:1
    (4, 4, 128, 32, 2),   # MHA
    (16, 1, 64, 8, 8),    # MQA
])
def test_paged_attention_sweep(dtype, tol, h, hkv, d, bs, m):
    b, nb = 3, 64
    q = jax.random.normal(KEY, (b, h, d)).astype(dtype)
    pk = jax.random.normal(jax.random.fold_in(KEY, 1), (nb, bs, hkv, d)).astype(dtype)
    pv = jax.random.normal(jax.random.fold_in(KEY, 2), (nb, bs, hkv, d)).astype(dtype)
    lengths = jnp.array([1, bs * m // 2 + 1, bs * m], jnp.int32)
    tables = jnp.where(
        jnp.arange(m)[None, :] * bs < lengths[:, None],
        jax.random.randint(jax.random.fold_in(KEY, 3), (b, m), 0, nb), -1
    ).astype(jnp.int32)
    o1 = pa_ref.paged_attention_ref(q, pk, pv, tables, lengths)
    o2 = paged_attention_pallas(q, pk, pv, tables, lengths, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        rtol=tol, atol=tol,
    )


def _fused_attn_case(key, t, c, p, b, nb, bs, h, hkv, d, dtype,
                     density=0.55):
    """A random fused-attention problem: a packed (T, C, P) index whose
    ptrs address a real KV pool, ragged chain lengths, a batch drawn
    from a subset of tenants (repeats allowed, some tenants inactive),
    and ragged kv lengths."""
    ks = [jax.random.fold_in(key, i) for i in range(9)]
    w0 = fmt.pack_entry(
        jax.random.randint(ks[0], (t, c, p), 0, nb).astype(jnp.uint32),
        jax.random.randint(ks[1], (t, c, p), 0, c).astype(jnp.uint32),
        allocated=jax.random.uniform(ks[2], (t, c, p)) < density,
        bfi_valid=jax.random.uniform(ks[3], (t, c, p)) < 0.7,
        zero=jax.random.uniform(ks[4], (t, c, p)) < 0.1,
    )[..., 0]
    chain_lengths = jax.random.randint(ks[5], (t,), 1, c + 1)
    tenants = jax.random.randint(ks[6], (b,), 0, t)
    kv_lengths = jax.random.randint(ks[7], (b,), 1, p * bs + 1)
    q = jax.random.normal(ks[8], (b, h, d)).astype(dtype)
    pk = jax.random.normal(ks[0], (nb, bs, hkv, d)).astype(dtype)
    pv = jax.random.normal(ks[1], (nb, bs, hkv, d)).astype(dtype)
    return q, pk, pv, w0, chain_lengths, tenants, kv_lengths


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("t,c,p,h,hkv,d,bs", [
    (4, 6, 128, 8, 2, 64, 8),     # GQA 4:1, multi-layer chains
    (3, 1, 128, 4, 4, 32, 4),     # MHA, C=1: direct-path degeneration
    (5, 9, 256, 16, 1, 64, 8),    # MQA, two lane tiles
])
def test_fused_chain_attention_sweep(dtype, tol, t, c, p, h, hkv, d, bs):
    b, nb = 3, 32
    key = jax.random.fold_in(KEY, t * c * p + h)
    q, pk, pv, w0, cl, tn, kl = _fused_attn_case(
        key, t, c, p, b, nb, bs, h, hkv, d, dtype)
    o1 = pa_ref.fused_chain_attention_ref(q, pk, pv, w0, cl, tn, kl)
    o2 = fused_chain_attention_pallas(q, pk, pv, w0, cl, tn, kl,
                                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        rtol=tol, atol=tol,
    )


def test_fused_chain_attention_all_masked_row():
    """A batch row whose entire chain misses (nothing allocated below its
    length) must come out all-zero from kernel and oracle alike."""
    t, c, p, b, nb, bs, h, hkv, d = 2, 3, 128, 2, 16, 4, 4, 2, 32
    q, pk, pv, w0, cl, tn, kl = _fused_attn_case(
        jax.random.fold_in(KEY, 77), t, c, p, b, nb, bs, h, hkv, d,
        jnp.float32)
    w0 = w0.at[1].set(0)          # tenant 1 owns nothing anywhere
    tn = jnp.array([0, 1], jnp.int32)
    o1 = pa_ref.fused_chain_attention_ref(q, pk, pv, w0, cl, tn, kl)
    o2 = fused_chain_attention_pallas(q, pk, pv, w0, cl, tn, kl,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(o2[1]), 0.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_fused_chain_attention_wrapper_pads_nonaligned_pages():
    """The always-kernel wrapper pads a non-lane-aligned page axis; the
    padded lanes are unallocated words the walk resolves to holes, so
    outputs match the unpadded oracle exactly."""
    t, c, p, b, nb, bs, h, hkv, d = 2, 3, 40, 2, 16, 4, 4, 2, 32
    q, pk, pv, w0, cl, tn, kl = _fused_attn_case(
        jax.random.fold_in(KEY, 40), t, c, p, b, nb, bs, h, hkv, d,
        jnp.float32)
    o1 = pa_ref.fused_chain_attention_ref(q, pk, pv, w0, cl, tn, kl)
    o2 = pa_ops.fused_chain_attention(q, pk, pv, w0, cl, tn, kl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("k,n", [(2, 128), (8, 256), (30, 640)])
def test_stream_merge_sweep(k, n):
    alloc = (jax.random.uniform(jax.random.fold_in(KEY, k), (k, n)) < 0.3
             ).astype(jnp.uint32)
    ptrs = jax.random.randint(KEY, (k, n), 0, 10_000).astype(jnp.uint32)
    f1, p1, s1 = sm_ref.merge_ref(alloc, ptrs, None)
    f2, p2, s2 = merge_pallas(alloc, ptrs, interpret=True)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_paged_attention_matches_dense_attention():
    """Paged attention over a contiguous table == ordinary decode attention."""
    from repro.models import layers as L

    b, h, hkv, d, bs, m = 2, 8, 4, 32, 8, 4
    s = bs * m
    q = jax.random.normal(KEY, (b, 1, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, d), jnp.float32)
    kv_len = 19
    dense = L.decode_attention_ref(q, k, v, kv_len)[:, 0]
    # lay K/V into per-sequence contiguous pool blocks
    pool_k = k.reshape(b * m, bs, hkv, d)
    pool_v = v.reshape(b * m, bs, hkv, d)
    tables = jnp.arange(b * m, dtype=jnp.int32).reshape(b, m)
    lengths = jnp.full((b,), kv_len, jnp.int32)
    paged = pa_ref.paged_attention_ref(q[:, 0], pool_k, pool_v, tables, lengths)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(paged),
                               rtol=2e-5, atol=2e-5)
