"""Paged KV cache COW forking + serving engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kvcache.paged import PagedKVCache, PagedKVConfig
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
KV = PagedKVConfig(n_layers=2, n_kv_heads=2, head_dim=8, block_size=4,
                   n_blocks=64, max_blocks_per_seq=8, dtype=jnp.float32)


def rand_kv(t):
    k = jax.random.normal(KEY, (KV.n_layers, t, KV.n_kv_heads, KV.head_dim))
    v = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (KV.n_layers, t, KV.n_kv_heads, KV.head_dim))
    return k, v


@pytest.mark.parametrize("scalable", [True, False])
def test_fork_shares_blocks_and_preserves_content(scalable):
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    k, v = rand_kv(10)
    cache.append_prefill(sid, k, v)
    used_before = cache.blocks_in_use()

    child = cache.fork(sid)
    # forking allocates no new data blocks (COW sharing, paper Fig 7)
    assert cache.blocks_in_use() == used_before

    ck, cv = cache.gather(child)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cv), np.asarray(v), rtol=1e-6)


@pytest.mark.parametrize("scalable", [True, False])
def test_divergent_writes_cow(scalable):
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    k, v = rand_kv(10)
    cache.append_prefill(sid, k, v)
    child = cache.fork(sid)

    k2, v2 = rand_kv(3)
    for t in range(3):
        cache.append(child, k2[:, t] * 7, v2[:, t] * 7)
    # parent untouched
    pk, _ = cache.gather(sid)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(k), rtol=1e-6)
    # child sees prefix + its own writes (position 10..12)
    ck, _ = cache.gather(child)
    np.testing.assert_allclose(np.asarray(ck[:, :10]), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ck[:, 10:13]),
                               np.asarray(k2 * 7), rtol=1e-6)


@pytest.mark.parametrize("scalable", [True, False])
def test_free_parent_with_live_fork_keeps_child_resolvable(scalable):
    """Regression: freeing a parent while a vanilla-forked child is live
    used to leave the child's ``parent`` pointer dangling — its next
    resolve raised KeyError and the chain walk lost every ancestor-owned
    block. The parent is now tombstoned until the last descendant goes."""
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    k, v = rand_kv(10)
    cache.append_prefill(sid, k, v)
    child = cache.fork(sid)
    cache.free_seq(sid)
    # the child still resolves and reads the full shared prefix
    ck, cv = cache.gather(child)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cv), np.asarray(v), rtol=1e-6)
    # COW through the tombstoned parent still works
    k2, v2 = rand_kv(1)
    cache.append(child, k2[:, 0], v2[:, 0])
    # freed parents reject further use
    with pytest.raises(KeyError):
        cache.append(sid, k2[:, 0], v2[:, 0])
    with pytest.raises(KeyError):
        cache.fork(sid)
    # the whole dead chain is reaped once the child goes: no block leaks
    cache.free_seq(child)
    assert cache.blocks_in_use() == 0


def test_free_seq_cascades_through_tombstoned_ancestors():
    cache = PagedKVCache(KV, scalable=False)
    a = cache.new_seq()
    k, v = rand_kv(6)
    cache.append_prefill(a, k, v)
    b = cache.fork(a)
    c = cache.fork(b)
    cache.free_seq(a)
    cache.free_seq(b)       # both tombstoned: c walks a <- b <- c
    ck, _ = cache.gather(c)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(k), rtol=1e-6)
    cache.free_seq(c)       # reaps c, then b, then a
    assert cache.blocks_in_use() == 0
    assert cache._seqs == {}


def test_prepare_write_advance_contract():
    """The engine-facing public API: prepare_write COWs the landing block,
    advance commits a token written externally (in-place scatter)."""
    cache = PagedKVCache(KV, scalable=True)
    sid = cache.new_seq()
    k, v = rand_kv(KV.block_size)        # exactly one full block
    cache.append_prefill(sid, k, v)
    child = cache.fork(sid)
    with pytest.raises(RuntimeError, match="prepare_write"):
        cache.advance(child)             # no prepared slot yet
    blk = cache.prepare_write(child)
    # the landing block is owned by the child and not shared with the parent
    assert int(cache._seqs[child].owner[1]) == child
    # simulate the decode step's in-place write, then commit
    tok_k, tok_v = rand_kv(1)
    cache.pool_k = cache.pool_k.at[:, blk, 0].set(tok_k[:, 0])
    cache.pool_v = cache.pool_v.at[:, blk, 0].set(tok_v[:, 0])
    cache.advance(child)
    assert cache.seq_length(child) == KV.block_size + 1
    ck, _ = cache.gather(child)
    np.testing.assert_allclose(np.asarray(ck[:, -1]), np.asarray(tok_k[:, 0]),
                               rtol=1e-6)
    # parent untouched
    pk, _ = cache.gather(sid)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(k), rtol=1e-6)
    # prepare_write is idempotent before the advance
    assert cache.prepare_write(child) == blk


def test_direct_fork_resolution_is_o1_vanilla_walks():
    deep_v = PagedKVCache(KV, scalable=False)
    deep_s = PagedKVCache(KV, scalable=True)
    for cache in (deep_v, deep_s):
        sid = cache.new_seq()
        k, v = rand_kv(8)
        cache.append_prefill(sid, k, v)
        for _ in range(6):  # fork chain of depth 6
            sid = cache.fork(sid)
        cache.lookup_count = 0
        cache.block_table(sid)
    assert deep_s.lookup_count * 3 < deep_v.lookup_count


def test_engine_forked_generation_matches_unforked():
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))

    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    a = eng.add_request(prompt)
    b = eng.fork_request(a)
    outs = [eng.step() for _ in range(4)]
    # identical prefixes + greedy decoding → forks agree at every step
    for o in outs:
        assert o[a] == o[b]
    stats = eng.memory_stats()
    assert stats["blocks_in_use"] < 2 * (9 // 4 + 1 + 4)  # shared prefix

    # reference: fresh engine, single sequence
    eng2 = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                  max_blocks_per_seq=16)
    c = eng2.add_request(prompt)
    outs2 = [eng2.step() for _ in range(4)]
    assert [o[a] for o in outs] == [o[c] for o in outs2]


def test_engine_padded_batch_matches_reference():
    """3 active sequences pad to a bucket of 4: the padded decode row
    (scratch pad_block, length 0) must not perturb live sequences."""
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    a = eng.add_request(prompt)
    b = eng.fork_request(a)
    c = eng.fork_request(a)
    outs = [eng.step() for _ in range(3)]
    for o in outs:                      # identical prefixes, greedy decode
        assert o[a] == o[b] == o[c]

    eng2 = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                  max_blocks_per_seq=16)
    d = eng2.add_request(prompt)
    outs2 = [eng2.step() for _ in range(3)]
    assert [o[a] for o in outs] == [o[d] for o in outs2]

    # padding without a reserved scratch block must be refused, and so
    # must a pad_block that was never actually reserved
    with pytest.raises(ValueError, match="pad_block"):
        eng.kv.batched_tables([a], pad_to=2)
    live_block = int(eng.kv._seqs[a].table[0])   # owned by sequence a
    with pytest.raises(ValueError, match="not reserved"):
        eng.kv.batched_tables([a], pad_to=2, pad_block=live_block)


def test_engine_drives_maintenance_between_steps():
    """A MaintenanceScheduler attached to the engine streams the fleet in
    the background without perturbing decoding: tokens match a scheduler-
    less engine bit-for-bit while the fleet's chains shrink."""
    import jax.numpy as jnp2
    from repro.core import fleet as fleet_lib
    from repro.core.scheduler import MaintenanceScheduler
    from repro.serve.engine import Engine

    spec = fleet_lib.FleetSpec(n_tenants=4, n_pages=64, page_size=4,
                               max_chain=8, pool_capacity=2048,
                               lease_quantum=8, l2_per_table=32)
    fl = fleet_lib.create(spec)
    ids = jnp2.broadcast_to(jnp2.arange(8, dtype=jnp2.int32)[None], (4, 8))
    for layer in range(5):
        fl = fleet_lib.write(fl, ids, jnp2.full((4, 8, 4), float(layer + 1)))
        if layer < 4:
            fl = fleet_lib.snapshot(fl)
    tenant_data = np.asarray(fleet_lib.materialize(fl))

    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))

    sched = MaintenanceScheduler(fl, max_tenants_per_tick=1)
    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16, scheduler=sched)
    ref = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    a, b = eng.add_request(prompt), ref.add_request(prompt)
    outs = [(eng.step()[a], ref.step()[b]) for _ in range(5)]
    assert all(x == y for x, y in outs)
    # the background plane really ran: one tenant streamed per step
    assert eng.last_maintenance is not None
    assert sched.tenants_streamed >= 4
    assert np.asarray(sched.fleet.length).tolist() == [2] * 4
    assert eng.memory_stats()["maintenance"]["quanta_reclaimed"] > 0
    np.testing.assert_allclose(np.asarray(fleet_lib.materialize(sched.fleet)),
                               tenant_data, rtol=1e-6)


def test_finish_request_releases_blocks_with_live_forks():
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    eng = Engine(cfg, params, scalable=False, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    a = eng.add_request(prompt)
    b = eng.fork_request(a)
    eng.finish_request(a)           # parent retires first (tombstoned)
    out = eng.step()
    assert list(out) == [b]         # the fork keeps decoding
    eng.finish_request(b)
    assert eng.memory_stats()["blocks_in_use"] == 0
    assert eng.step() == {}


def test_idle_engine_still_drains_maintenance_backlog():
    """step() with no active sequences must still tick the scheduler —
    idle polling is the cheapest time for background work."""
    from repro.core import fleet as fleet_lib
    from repro.core.scheduler import MaintenanceScheduler
    from repro.serve.engine import Engine

    spec = fleet_lib.FleetSpec(n_tenants=2, n_pages=64, page_size=4,
                               max_chain=8, pool_capacity=512,
                               lease_quantum=8, l2_per_table=32)
    fl = fleet_lib.create(spec)
    ids = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32)[None], (2, 4))
    for layer in range(4):
        fl = fleet_lib.write(fl, ids, jnp.ones((2, 4, 4)))
        if layer < 3:
            fl = fleet_lib.snapshot(fl)

    cfg = smoke_config("qwen2-7b")
    sched = MaintenanceScheduler(fl, max_tenants_per_tick=1)
    eng = Engine(cfg, get_model(cfg).init(KEY), n_blocks=64, block_size=4,
                 max_blocks_per_seq=16, scheduler=sched)
    assert eng.step() == {}                 # idle, but the tick ran
    assert sched.ticks == 1
    while sched.candidates():
        eng.step()
    assert np.asarray(sched.fleet.length).tolist() == [2, 2]


def test_engine_matches_dense_decode_path():
    """Paged serving must agree with the dense-cache decode_step."""
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    sid = eng.add_request(prompt)
    paged_tokens = [eng.active[sid][-1]]
    for _ in range(3):
        paged_tokens.append(eng.step()[sid])

    # dense reference
    import jax.tree_util as jtu
    batch = dict(tokens=jnp.asarray(prompt, jnp.int32)[None])
    logits, cache = jax.jit(model.prefill)(params, batch)
    fixed = model.init_cache(1, 9 + 8)
    cache = jtu.tree_map(
        lambda d, s: s if d.shape == s.shape
        else d.at[tuple(slice(0, x) for x in s.shape)].set(s.astype(d.dtype)),
        fixed, cache)
    dense_tokens = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        nt = jnp.asarray([[dense_tokens[-1]]], jnp.int32)
        logits, cache = jax.jit(model.decode_step)(params, cache, nt)
        dense_tokens.append(int(jnp.argmax(logits[0])))
    assert paged_tokens == dense_tokens


def test_kvcache_property_random_ops():
    """Property test: random fork/append interleavings vs a python reference
    model, for both fork strategies."""
    pytest.importorskip("hypothesis",
                        reason="install extras: pip install -e .[test]")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=15)
    @given(st.lists(
        st.one_of(
            st.tuples(st.just("append"), st.integers(0, 3)),
            st.tuples(st.just("fork"), st.integers(0, 3)),
        ), min_size=1, max_size=12), st.booleans())
    def run(ops, scalable):
        cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4,
                            block_size=2, n_blocks=256,
                            max_blocks_per_seq=16, dtype=jnp.float32)
        cache = PagedKVCache(cfg, scalable=scalable)
        sids = [cache.new_seq()]
        ref: dict[int, list[float]] = {sids[0]: []}
        counter = [0.0]
        for kind, which in ops:
            sid = sids[which % len(sids)]
            if kind == "fork":
                if len(sids) >= 6:
                    continue
                child = cache.fork(sid)
                sids.append(child)
                ref[child] = list(ref[sid])
            else:
                if len(ref[sid]) >= 30:
                    continue
                counter[0] += 1.0
                val = counter[0]
                arr = jnp.full((1, 1, 4), val, jnp.float32)
                cache.append(sid, arr, arr)
                ref[sid].append(val)
        for sid in sids:
            k, _ = cache.gather(sid)
            got = np.asarray(k[0, :, 0, 0])
            np.testing.assert_allclose(got, np.asarray(ref[sid]),
                                       err_msg=f"sid={sid} scalable={scalable}")

    run()
