"""Paged KV cache COW forking + serving engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kvcache.paged import PagedKVCache, PagedKVConfig
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
KV = PagedKVConfig(n_layers=2, n_kv_heads=2, head_dim=8, block_size=4,
                   n_blocks=64, max_blocks_per_seq=8, dtype=jnp.float32)


def rand_kv(t):
    k = jax.random.normal(KEY, (KV.n_layers, t, KV.n_kv_heads, KV.head_dim))
    v = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (KV.n_layers, t, KV.n_kv_heads, KV.head_dim))
    return k, v


@pytest.mark.parametrize("scalable", [True, False])
def test_fork_shares_blocks_and_preserves_content(scalable):
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    k, v = rand_kv(10)
    cache.append_prefill(sid, k, v)
    used_before = cache.blocks_in_use()

    child = cache.fork(sid)
    # forking allocates no new data blocks (COW sharing, paper Fig 7)
    assert cache.blocks_in_use() == used_before

    ck, cv = cache.gather(child)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cv), np.asarray(v), rtol=1e-6)


@pytest.mark.parametrize("scalable", [True, False])
def test_divergent_writes_cow(scalable):
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    k, v = rand_kv(10)
    cache.append_prefill(sid, k, v)
    child = cache.fork(sid)

    k2, v2 = rand_kv(3)
    for t in range(3):
        cache.append(child, k2[:, t] * 7, v2[:, t] * 7)
    # parent untouched
    pk, _ = cache.gather(sid)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(k), rtol=1e-6)
    # child sees prefix + its own writes (position 10..12)
    ck, _ = cache.gather(child)
    np.testing.assert_allclose(np.asarray(ck[:, :10]), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ck[:, 10:13]),
                               np.asarray(k2 * 7), rtol=1e-6)


def test_direct_fork_resolution_is_o1_vanilla_walks():
    deep_v = PagedKVCache(KV, scalable=False)
    deep_s = PagedKVCache(KV, scalable=True)
    for cache in (deep_v, deep_s):
        sid = cache.new_seq()
        k, v = rand_kv(8)
        cache.append_prefill(sid, k, v)
        for _ in range(6):  # fork chain of depth 6
            sid = cache.fork(sid)
        cache.lookup_count = 0
        cache.block_table(sid)
    assert deep_s.lookup_count * 3 < deep_v.lookup_count


def test_engine_forked_generation_matches_unforked():
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))

    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    a = eng.add_request(prompt)
    b = eng.fork_request(a)
    outs = [eng.step() for _ in range(4)]
    # identical prefixes + greedy decoding → forks agree at every step
    for o in outs:
        assert o[a] == o[b]
    stats = eng.memory_stats()
    assert stats["blocks_in_use"] < 2 * (9 // 4 + 1 + 4)  # shared prefix

    # reference: fresh engine, single sequence
    eng2 = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                  max_blocks_per_seq=16)
    c = eng2.add_request(prompt)
    outs2 = [eng2.step() for _ in range(4)]
    assert [o[a] for o in outs] == [o[c] for o in outs2]


def test_engine_padded_batch_matches_reference():
    """3 active sequences pad to a bucket of 4: the padded decode row
    (scratch pad_block, length 0) must not perturb live sequences."""
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    a = eng.add_request(prompt)
    b = eng.fork_request(a)
    c = eng.fork_request(a)
    outs = [eng.step() for _ in range(3)]
    for o in outs:                      # identical prefixes, greedy decode
        assert o[a] == o[b] == o[c]

    eng2 = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                  max_blocks_per_seq=16)
    d = eng2.add_request(prompt)
    outs2 = [eng2.step() for _ in range(3)]
    assert [o[a] for o in outs] == [o[d] for o in outs2]

    # padding without a reserved scratch block must be refused, and so
    # must a pad_block that was never actually reserved
    with pytest.raises(ValueError, match="pad_block"):
        eng.kv.batched_tables([a], pad_to=2)
    live_block = int(eng.kv._seqs[a].table[0])   # owned by sequence a
    with pytest.raises(ValueError, match="not reserved"):
        eng.kv.batched_tables([a], pad_to=2, pad_block=live_block)


def test_engine_matches_dense_decode_path():
    """Paged serving must agree with the dense-cache decode_step."""
    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(KEY)
    from repro.serve.engine import Engine

    prompt = np.asarray(jax.random.randint(KEY, (9,), 0, cfg.vocab_size))
    eng = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    sid = eng.add_request(prompt)
    paged_tokens = [eng.active[sid][-1]]
    for _ in range(3):
        paged_tokens.append(eng.step()[sid])

    # dense reference
    import jax.tree_util as jtu
    batch = dict(tokens=jnp.asarray(prompt, jnp.int32)[None])
    logits, cache = jax.jit(model.prefill)(params, batch)
    fixed = model.init_cache(1, 9 + 8)
    cache = jtu.tree_map(
        lambda d, s: s if d.shape == s.shape
        else d.at[tuple(slice(0, x) for x in s.shape)].set(s.astype(d.dtype)),
        fixed, cache)
    dense_tokens = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        nt = jnp.asarray([[dense_tokens[-1]]], jnp.int32)
        logits, cache = jax.jit(model.decode_step)(params, cache, nt)
        dense_tokens.append(int(jnp.argmax(logits[0])))
    assert paged_tokens == dense_tokens


def test_kvcache_property_random_ops():
    """Property test: random fork/append interleavings vs a python reference
    model, for both fork strategies."""
    pytest.importorskip("hypothesis",
                        reason="install extras: pip install -e .[test]")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=15)
    @given(st.lists(
        st.one_of(
            st.tuples(st.just("append"), st.integers(0, 3)),
            st.tuples(st.just("fork"), st.integers(0, 3)),
        ), min_size=1, max_size=12), st.booleans())
    def run(ops, scalable):
        cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4,
                            block_size=2, n_blocks=256,
                            max_blocks_per_seq=16, dtype=jnp.float32)
        cache = PagedKVCache(cfg, scalable=scalable)
        sids = [cache.new_seq()]
        ref: dict[int, list[float]] = {sids[0]: []}
        counter = [0.0]
        for kind, which in ops:
            sid = sids[which % len(sids)]
            if kind == "fork":
                if len(sids) >= 6:
                    continue
                child = cache.fork(sid)
                sids.append(child)
                ref[child] = list(ref[sid])
            else:
                if len(ref[sid]) >= 30:
                    continue
                counter[0] += 1.0
                val = counter[0]
                arr = jnp.full((1, 1, 4), val, jnp.float32)
                cache.append(sid, arr, arr)
                ref[sid].append(val)
        for sid in sids:
            k, _ = cache.gather(sid)
            got = np.asarray(k[0, :, 0, 0])
            np.testing.assert_allclose(got, np.asarray(ref[sid]),
                                       err_msg=f"sid={sid} scalable={scalable}")

    run()
