"""End-to-end behaviour tests for the reproduced system.

The paper's two headline claims, exercised through the public API:
1. read cost through a snapshot chain is O(chain) vanilla vs O(1) direct;
2. index-cache memory is O(chain) per-file vs O(1) unified;
plus the full train→checkpoint→crash→restore→serve lifecycle.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache, resolve, store
from repro.core.cache import cache_memory_bytes


def _build_chain(length, *, scalable, n_pages=256):
    ch = store.create(n_pages=n_pages, page_size=8, max_chain=length + 1,
                      scalable=scalable, pool_capacity=n_pages * 8)
    key = jax.random.PRNGKey(0)
    per = max(1, n_pages // max(length, 1) // 2)
    for i in range(length):
        ids = jax.random.choice(jax.random.fold_in(key, i), n_pages, (per,),
                                replace=False).astype(jnp.int32)
        ch = store.write(ch, ids, jnp.full((per, 8), float(i + 1)))
        if i < length - 1:
            ch = store.snapshot(ch)
    return ch


def test_claim1_lookup_cost_scaling():
    ids = jnp.arange(256, dtype=jnp.int32)
    for n in (4, 16, 48):
        chv = _build_chain(n, scalable=False)
        chs = _build_chain(n, scalable=True)
        lv = int(jnp.sum(resolve.resolve_vanilla(chv, ids).lookups))
        ld = int(jnp.sum(resolve.resolve_direct(chs, ids).lookups))
        assert ld == 256                     # O(1) per request, any chain
        assert lv > 256 * (n // 4)           # grows with the chain
        # and the two return identical data
        np.testing.assert_allclose(
            np.asarray(store.materialize(chv, method="vanilla")),
            np.asarray(store.materialize(chs, method="direct")),
        )


def test_claim2_memory_scaling():
    spec = _build_chain(4, scalable=False).spec
    v500 = cache_memory_bytes(spec, 64, 500, unified=False)
    u500 = cache_memory_bytes(spec, 64, 500, unified=True)
    assert v500 / u500 > 10  # paper reports 15.2x at length 500


def test_full_lifecycle_train_crash_restore_serve():
    import pytest
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig
    from repro.models import get_model
    from repro.optim.adamw import AdamWConfig
    from repro.serve.engine import Engine
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = smoke_config("qwen2-7b")
    model = get_model(cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    tcfg = TrainerConfig(total_steps=6, ckpt_every=2, page_size=256)
    trainer = Trainer(model, AdamWConfig(lr=1e-3), dcfg, tcfg, seed=0)
    with pytest.raises(RuntimeError):
        trainer.run(crash_after=3)
    assert trainer.resume() == 2
    report = trainer.run()
    assert report["steps"] == 6
    assert np.isfinite(report["final_loss"])
    assert report["goodput"] > 0

    # serve the trained weights with a forked (COW) request pair
    eng = Engine(cfg, trainer.params, scalable=True, n_blocks=64,
                 block_size=4, max_blocks_per_seq=16)
    prompt = np.arange(5) % cfg.vocab_size
    a = eng.add_request(prompt)
    b = eng.fork_request(a)
    toks = eng.step()
    assert toks[a] == toks[b]
