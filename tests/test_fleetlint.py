"""fleetlint rule fixtures: every rule has at least one triggering,
one non-triggering, and one disable-comment case, plus a whole-repo
run asserting the tree itself is clean and a CLI exit-status check."""

import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import run_lint

REPO = Path(__file__).resolve().parents[1]


def lint(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint(tmp_path)


def codes(findings):
    return sorted({f.code for f in findings})


# ------------------------------------------------------------- FL001

FL001_BAD = """
    def unpack(entry):
        ptr = entry & 268435455
        cold = entry & (1 << 29)
        return ptr, cold
"""


def test_fl001_triggers_on_raw_mask_and_shift(tmp_path):
    fs = lint(tmp_path, {"core/other.py": FL001_BAD})
    assert codes(fs) == ["FL001"] and len(fs) >= 2
    assert fs[0].relpath == "core/other.py"
    assert fs[0].line == 3


def test_fl001_exempts_format_module_and_plain_sizes(tmp_path):
    assert lint(tmp_path, {
        "core/format.py": FL001_BAD,          # the bits' one home
        "configs/model.py": "vocab_size = 65536\nrows = 1 << 8\n",
    }) == []


def test_fl001_bfi_mask_only_in_bitwise_context(tmp_path):
    assert lint(tmp_path, {"a.py": "n = 65535\n"}) == []
    fs = lint(tmp_path, {"b.py": "n = x & 65535\n"})
    assert codes(fs) == ["FL001"]


def test_fl001_disable_comment(tmp_path):
    assert lint(tmp_path, {"core/other.py": """
        ptr = entry & 268435455  # fleetlint: disable=FL001
    """}) == []


# ------------------------------------------------------------- FL002

FL002_BAD = """
    import jax.numpy as jnp

    class Engine:
        def step(self):
            return helper()

    def helper():
        v = jnp.sum(jnp.ones(3))
        return int(v)
"""


def test_fl002_triggers_via_call_graph(tmp_path):
    fs = lint(tmp_path, {"serve/engine.py": FL002_BAD})
    assert codes(fs) == ["FL002"]
    assert fs[0].line == 10  # the int(v) line, inside helper

def test_fl002_ignores_functions_off_the_hot_path(tmp_path):
    assert lint(tmp_path, {"serve/cold.py": """
        import jax.numpy as jnp

        def offline_report():
            v = jnp.sum(jnp.ones(3))
            return int(v)
    """}) == []


def test_fl002_synced_values_are_clean_downstream(tmp_path):
    # np.asarray IS the sync (one finding); int() of its host result isn't
    fs = lint(tmp_path, {"serve/engine.py": """
        import numpy as np, jax.numpy as jnp

        class Engine:
            def step(self):
                nxt = np.asarray(jnp.argmax(x))
                return int(nxt[0])
    """})
    assert [f.code for f in fs] == ["FL002"]
    assert "np.asarray" in fs[0].message


def test_fl002_scheduler_tick_is_a_boundary(tmp_path):
    assert lint(tmp_path, {"core/sched.py": """
        import numpy as np, jax.numpy as jnp

        class Engine:
            def step(self):
                self.scheduler.tick()

        class MaintenanceScheduler:
            def tick(self):
                return float(jnp.sum(jnp.ones(2)))
    """}) == []


def test_fl002_disable_on_sink_line_and_def_line(tmp_path):
    assert lint(tmp_path, {"serve/engine.py": """
        import jax.numpy as jnp

        class Engine:
            def step(self):
                v = jnp.sum(jnp.ones(3))
                return int(v)  # fleetlint: disable=FL002
    """}) == []
    # a waived def is a traversal boundary
    assert lint(tmp_path, {"serve/engine2.py": """
        import jax.numpy as jnp

        class Engine:
            def step(self):  # fleetlint: disable=FL002
                return int(jnp.sum(jnp.ones(3)))
    """}) == []


# ------------------------------------------------------------- FL003

def test_fl003_triggers_on_mutable_closure_and_shape_branch(tmp_path):
    fs = lint(tmp_path, {"models/fast.py": """
        import jax

        _CACHE = {}

        @jax.jit
        def f(x):
            return _CACHE["w"] + x

        @jax.jit
        def g(x):
            if x.shape[0] > 4:
                return x + 1
            return x
    """})
    assert codes(fs) == ["FL003"] and len(fs) == 2


def test_fl003_ignores_unjitted_functions_and_locals(tmp_path):
    assert lint(tmp_path, {"models/slow.py": """
        import jax

        _CACHE = {}

        def warm(x):
            return _CACHE.setdefault("w", x)

        @jax.jit
        def f(x):
            acc = {}
            acc["y"] = x
            return acc["y"]
    """}) == []


def test_fl003_disable_comment(tmp_path):
    assert lint(tmp_path, {"models/fast.py": """
        import jax

        _TABLE = [1, 2, 3]

        @jax.jit
        def f(x):
            # frozen at trace time on purpose
            return x + _TABLE[0]  # fleetlint: disable=FL003
    """}) == []


# ------------------------------------------------------------- FL004

def test_fl004_triggers_outside_owner_modules(tmp_path):
    fs = lint(tmp_path, {"serve/other.py": """
        def hack(kv, fleet):
            kv.pool_k = 1
            fleet._free.append(3)
    """})
    assert codes(fs) == ["FL004"] and len(fs) == 2


def test_fl004_owners_may_write_their_state(tmp_path):
    assert lint(tmp_path, {"kvcache/paged.py": """
        class PagedKVCache:
            def commit(self, pk):
                self.pool_k = pk
    """}) == []


def test_fl004_disable_comment(tmp_path):
    assert lint(tmp_path, {"serve/other.py": """
        def hack(kv):
            kv.pool_k = 1  # fleetlint: disable=FL004
    """}) == []


# ------------------------------------------------------------- FL005

FL005_BAD = """
    import jax.experimental.pallas as pl

    TRACE = []

    def _kern(x_ref, o_ref):
        print("tracing")
        TRACE.append(1)
        o_ref[...] = x_ref[...]

    def run(x):
        return pl.pallas_call(_kern, out_shape=x)(x)
"""


def test_fl005_triggers_on_impure_kernel_body(tmp_path):
    fs = lint(tmp_path, {"kernels/k.py": FL005_BAD})
    fl5 = [f for f in fs if f.code == "FL005"]
    assert len(fl5) == 2  # print + append
    # closing over the mutable TRACE global is also a retrace hazard
    assert codes(fs) == ["FL003", "FL005"]


def test_fl005_pure_kernel_and_index_map_are_clean(tmp_path):
    assert lint(tmp_path, {"kernels/k.py": """
        import jax.experimental.pallas as pl

        def _kern(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2

        def run(x):
            return pl.pallas_call(
                _kern,
                in_specs=[pl.BlockSpec((8, 128), lambda t: (t, 0))],
                out_shape=x,
            )(x)
    """}) == []


def test_fl005_triggers_on_impure_index_map(tmp_path):
    fs = lint(tmp_path, {"kernels/k.py": """
        import jax.experimental.pallas as pl

        def _kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x, offsets):
            return pl.pallas_call(
                _kern,
                in_specs=[pl.BlockSpec((8,), lambda t: (offsets[t],))],
                out_shape=x,
            )(x)
    """})
    assert codes(fs) == ["FL005"]


def test_fl005_disable_comment(tmp_path):
    fs = lint(tmp_path, {"kernels/k.py": """
        import jax.experimental.pallas as pl

        def _kern(x_ref, o_ref):
            print("dbg")  # fleetlint: disable=FL005
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(_kern, out_shape=x)(x)
    """})
    assert fs == []


# ----------------------------------------------------- whole repo + CLI

def test_repo_tree_is_clean():
    assert run_lint(REPO / "src") == []


def test_cli_exits_nonzero_with_code_and_location(tmp_path):
    (tmp_path / "serve").mkdir(parents=True)
    (tmp_path / "serve" / "engine.py").write_text(textwrap.dedent(FL002_BAD))
    (tmp_path / "core").mkdir()
    (tmp_path / "core" / "bits.py").write_text("m = x & 268435455\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "fleetlint.py"), str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "core/bits.py:1" in proc.stdout and "FL001" in proc.stdout
    assert "serve/engine.py:10" in proc.stdout and "FL002" in proc.stdout


def test_cli_clean_tree_exits_zero(tmp_path):
    (tmp_path / "ok.py").write_text("x = 1\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "fleetlint.py"), str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
