"""Golden-prefix registry: content addressing, fork pins, freeze guards.

Contracts under test:

* ``PrefixTrie``: radix lookup returns the *deepest* registered prefix,
  path-compressed edges split/merge correctly under insert/remove;
* ``GoldenRegistry``: registration is content-addressed (identical
  chains hash identically regardless of pool layout), forks pin exactly
  the layers they alias (full and partial depth), the lifecycle guards
  (free/unregister/re-register) refuse every unsafe transition;
* maintenance bit-preservation: compact/stream/demote with the registry
  never move or spill a pinned row, so a frozen base's fingerprint and
  every fork's view survive the whole maintenance plane — including the
  demote/fork race the per-layer refcounts exist to win;
* ``check_fleet_invariants``/``check_kv_invariants`` catch golden-state
  corruption (mutated frozen owner, drifted refcounts, flag drift);
* the serving plane: ``PagedKVCache.register_golden`` freezes a
  sequence (append/decode-prepare/free all refuse), forks of it decode
  on, ``prepare_step_single`` is bit-identical to the batched prepare,
  and ``Engine.add_request`` admission off a golden base is bitwise
  equal to a duplicate-storage oracle running the same suffix dispatch.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import fleet, store
from repro.core.golden import GoldenRegistry, PrefixTrie
from repro.core.invariants import check_fleet_invariants, check_kv_invariants
from repro.core.metrics import golden_residency
from repro.core.migrate import tenant_fingerprint
from repro.core.scheduler import MaintenanceScheduler
from repro.kvcache.paged import PagedKVCache, PagedKVConfig
from repro.models.api import get_model
from repro.serve.engine import Engine

N_PAGES, PAGE = 32, 4


# -- PrefixTrie ---------------------------------------------------------------


def test_trie_longest_prefix_picks_deepest():
    t = PrefixTrie()
    t.insert([1, 2], "short")
    t.insert([1, 2, 3, 4], "long")
    assert t.longest_prefix([1, 2, 3, 4, 9]) == (4, "long")
    assert t.longest_prefix([1, 2, 3]) == (2, "short")
    assert t.longest_prefix([1, 9]) == (0, None)
    assert len(t) == 2


def test_trie_edge_split_on_divergence():
    t = PrefixTrie()
    t.insert([5, 6, 7, 8], "a")
    t.insert([5, 6, 9], "b")       # splits the compressed [5,6,7,8] edge
    assert t.longest_prefix([5, 6, 7, 8]) == (4, "a")
    assert t.longest_prefix([5, 6, 9, 1]) == (3, "b")
    assert t.longest_prefix([5, 6]) == (0, None)


def test_trie_remove_and_guards():
    t = PrefixTrie()
    t.insert([1, 2, 3], "x")
    with pytest.raises(ValueError):
        t.insert([], "empty")
    with pytest.raises(ValueError):
        t.insert([1, 2, 3], "other")   # same key, different value
    t.remove([1, 2, 3])
    assert t.longest_prefix([1, 2, 3]) == (0, None)
    assert len(t) == 0
    with pytest.raises(KeyError):
        t.remove([1, 2, 3])


# -- fleet-plane registry -----------------------------------------------------


def make_fleet(n_tenants=4, *, scalable=True, pool_capacity=512,
               max_chain=6):
    spec = fleet.FleetSpec(
        n_tenants=n_tenants, n_pages=N_PAGES, page_size=PAGE,
        max_chain=max_chain, pool_capacity=pool_capacity,
        lease_quantum=8, l2_per_table=N_PAGES,
    )
    return fleet.create(spec, scalable=jnp.asarray(scalable, bool))


def write_layers(fl, t, layers, *, writes=6, seed=0):
    """Write+snapshot ``layers`` times on tenant ``t`` only; returns the
    fleet and the tenant's expected page->row view."""
    rng = np.random.default_rng(seed)
    n_t = fl.spec.n_tenants
    mask = np.zeros(n_t, bool)
    mask[t] = True
    view = {}
    for layer in range(layers):
        ids = np.broadcast_to(
            rng.choice(N_PAGES, writes, replace=False).astype(np.int32),
            (n_t, writes))
        # tenant-independent bytes: two tenants grown with the same seed
        # hold bit-identical chains (the content-addressing fixture)
        data = np.broadcast_to(
            rng.standard_normal((writes, PAGE)).astype(np.float32),
            (n_t, writes, PAGE))
        fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data),
                         jnp.asarray(mask))
        for i in range(writes):
            view[int(ids[t, i])] = data[t, i].copy()
        if layer < layers - 1:
            fl = fleet.snapshot(fl, jnp.asarray(mask))
    return fl, view


def tenant_view(fl, t):
    grid = np.broadcast_to(np.arange(N_PAGES, dtype=np.int32),
                           (fl.spec.n_tenants, N_PAGES))
    return np.asarray(fleet.read(fl, grid)[0])[t]


def view_from(pages):
    out = np.zeros((N_PAGES, PAGE), np.float32)
    for p, row in pages.items():
        out[p] = row
    return out


@pytest.mark.parametrize("scalable", [False, True])
def test_register_is_content_addressed(scalable):
    """Two tenants written identically hash to the same gid even though
    their pool rows differ; a third, different tenant does not."""
    fl = make_fleet(scalable=scalable)
    fl, _ = write_layers(fl, 0, 3, seed=1)
    fl, _ = write_layers(fl, 1, 3, seed=1)    # same content, other rows
    fl, _ = write_layers(fl, 2, 3, seed=2)    # different content
    reg = GoldenRegistry()
    gid0, created0 = reg.register(fl, 0)
    gid1, created1 = reg.register(fl, 1)
    gid2, created2 = reg.register(fl, 2)
    assert created0 and not created1 and created2
    assert gid0 == gid1 != gid2
    # the duplicate tenant was NOT recorded as an owner: it stays an
    # ordinary tenant the caller can free or fork-from-the-original
    assert reg.is_golden_owner(0) and not reg.is_golden_owner(1)
    check_fleet_invariants(fl, registry=reg)


@pytest.mark.parametrize("scalable", [False, True])
def test_fork_aliases_base_and_overlays_cow(scalable):
    fl = make_fleet(scalable=scalable)
    fl, base_view = write_layers(fl, 0, 3, seed=3)
    reg = GoldenRegistry()
    gid, _ = reg.register(fl, 0)
    fl = reg.fork(fl, gid, 2)
    check_fleet_invariants(fl, registry=reg)
    assert np.array_equal(tenant_view(fl, 2), view_from(base_view))
    # COW overlay: the fork writes, the frozen base must not move
    mask = np.zeros(4, bool)
    mask[2] = True
    ids = np.zeros((4, 2), np.int32)
    ids[2] = [0, 1]
    data = np.full((4, 2, PAGE), 9.0, np.float32)
    fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data),
                     jnp.asarray(mask))
    check_fleet_invariants(fl, registry=reg)
    got = tenant_view(fl, 2)
    assert (got[0] == 9.0).all() and (got[1] == 9.0).all()
    assert np.array_equal(tenant_view(fl, 0), view_from(base_view))
    st = reg.stats()
    assert st["golden_forks"] == 1
    assert st["dedup_rows_saved"] > 0
    res = golden_residency(reg)
    assert res.dedup_rows_saved == st["dedup_rows_saved"]
    assert res.golden_chains == 1


def test_partial_depth_fork_pins_only_lower_layers():
    fl = make_fleet(scalable=True)
    fl, _ = write_layers(fl, 0, 4, seed=4)
    reg = GoldenRegistry()
    gid, _ = reg.register(fl, 0)
    ch = reg._chains[gid]
    fl = reg.fork(fl, gid, 1, depth=2)
    assert np.array_equal(ch.layer_refs,
                          np.array([1, 1, 0, 0], np.int64))
    shared = reg.shared_rows_for(1)
    assert np.array_equal(shared, ch.cum_rows[1])
    assert shared.size < ch.rows.size   # deeper layers are NOT pinned
    check_fleet_invariants(fl, registry=reg)
    reg.release(1)
    assert not ch.layer_refs.any()


def test_lifecycle_guards():
    fl = make_fleet()
    fl, _ = write_layers(fl, 0, 2, seed=5)
    reg = GoldenRegistry()
    gid, _ = reg.register(fl, 0)
    fl = reg.fork(fl, gid, 1)
    # a frozen owner cannot be freed while registered
    with pytest.raises(ValueError, match="golden"):
        fleet.free_tenant(fl, 0, registry=reg)
    # a fork aliases foreign rows: it can never itself be registered
    with pytest.raises(ValueError, match="fork"):
        reg.register(fl, 1)
    # an owner/fork slot is not a legal fork destination
    with pytest.raises(ValueError, match="slot"):
        reg.fork(fl, gid, 1)
    # a pinned chain cannot be unregistered
    with pytest.raises(ValueError, match="forks"):
        reg.unregister(gid)
    with pytest.raises(ValueError, match="depth"):
        reg.fork(fl, gid, 2, depth=99)
    # freeing the fork releases its pins; then the chain can go
    fl = fleet.free_tenant(fl, 1, registry=reg)
    reg.unregister(gid)
    fl = fleet.free_tenant(fl, 0, registry=reg)
    check_fleet_invariants(fl, registry=reg)


@pytest.mark.parametrize("scalable", [False, True])
def test_maintenance_preserves_frozen_base(scalable):
    """compact + stream + demote with the registry must leave the owner
    bit-frozen (same fingerprint) and every fork's view intact."""
    fl = make_fleet(scalable=scalable)
    fl, base_view = write_layers(fl, 0, 3, seed=6)
    fl, _ = write_layers(fl, 3, 3, seed=7)    # churn neighbour
    st = store.TieredStore.for_fleet(fl.spec)
    reg = GoldenRegistry()
    gid, _ = reg.register(fl, 0, store=st)
    fp = reg._chains[gid].fingerprint
    fl = reg.fork(fl, gid, 1, store=st)
    fl = fleet.compact(fl, registry=reg)
    fl = fleet.stream_tenants(fl, np.ones(4, bool), 1, registry=reg)
    fl, rep = fleet.demote_tenants(fl, st, [0, 1, 3], registry=reg)
    check_fleet_invariants(fl, store=st, registry=reg)
    assert tenant_fingerprint(fl, 0) == fp
    assert np.array_equal(tenant_view(fl, 1), view_from(base_view))
    # the neighbour DID demote — the exclusion is per-row, not global
    assert rep["rows_demoted"] > 0


def test_demote_fork_race_never_spills_pinned_rows():
    """The regression the refcounts exist for: a fork's lower layers are
    immutable-below-active — exactly demotion's eligibility shape — but
    spilling them would yank the base from under every sibling fork."""
    fl = make_fleet(scalable=True)
    fl, _ = write_layers(fl, 0, 3, seed=8)
    st = store.TieredStore.for_fleet(fl.spec)
    reg = GoldenRegistry()
    gid, _ = reg.register(fl, 0, store=st)
    fl = reg.fork(fl, gid, 1, store=st)
    fl = fleet.snapshot(fl, jnp.asarray([False, True, False, False]))
    # owner pick: skipped wholesale; fork pick: pinned rows excluded
    fl, rep0 = fleet.demote_tenants(fl, st, [0], registry=reg)
    assert rep0["rows_demoted"] == 0
    fl, rep1 = fleet.demote_tenants(fl, st, [1], registry=reg)
    # the fork's below-active layers are ALL pinned base rows — demotion
    # found nothing legal to spill
    assert rep1["rows_demoted"] == 0
    assert int(fl.cold_count[0]) == 0 and int(fl.cold_count[1]) == 0
    check_fleet_invariants(fl, store=st, registry=reg)
    # and the scheduler's budget-pressure demotion honours the same pins
    sched = MaintenanceScheduler(fl, store=st, device_page_budget=1,
                                 demote_rows_per_tick=64, registry=reg)
    for _ in range(4):
        sched.tick()
    check_fleet_invariants(sched.fleet, store=st, registry=reg)
    assert tenant_fingerprint(sched.fleet, 0) == \
        reg._chains[gid].fingerprint


def test_invariants_catch_mutated_frozen_owner():
    fl = make_fleet()
    fl, _ = write_layers(fl, 0, 2, seed=9)
    reg = GoldenRegistry()
    reg.register(fl, 0)
    mask = np.zeros(4, bool)
    mask[0] = True
    ids = np.zeros((4, 1), np.int32)
    data = np.ones((4, 1, PAGE), np.float32)
    broken = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data),
                         jnp.asarray(mask))     # write on a frozen base
    with pytest.raises(AssertionError, match="mutated"):
        check_fleet_invariants(broken, registry=reg)


def test_invariants_catch_refcount_drift():
    fl = make_fleet()
    fl, _ = write_layers(fl, 0, 2, seed=10)
    reg = GoldenRegistry()
    gid, _ = reg.register(fl, 0)
    fl = reg.fork(fl, gid, 1)
    reg._chains[gid].layer_refs[0] += 1          # the deliberate drift
    with pytest.raises(AssertionError, match="refcounts"):
        check_fleet_invariants(fl, registry=reg)


# -- serving plane: PagedKVCache ---------------------------------------------


def kv_cache(scalable, *, n_blocks=64, max_blocks=8):
    cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=8, block_size=4,
                        n_blocks=n_blocks, max_blocks_per_seq=max_blocks,
                        dtype=jnp.float32)
    return PagedKVCache(cfg, scalable=scalable, resolver="gather")


def rand_kv(n, seed):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.standard_normal((1, n, 1, 8)), jnp.float32),
            jnp.asarray(r.standard_normal((1, n, 1, 8)), jnp.float32))


@pytest.mark.parametrize("scalable", [False, True])
def test_kv_register_freezes_sequence(scalable):
    kv = kv_cache(scalable)
    sid = kv.new_seq()
    k, v = rand_kv(8, 11)
    kv.append_prefill(sid, k, v)
    h = kv.register_golden(sid)
    assert kv.register_golden(sid) == h          # idempotent
    assert kv.is_golden(sid)
    with pytest.raises(RuntimeError, match="frozen"):
        kv.append_prefill(sid, k, v)
    with pytest.raises(RuntimeError, match="frozen"):
        kv.prepare_step([sid])
    with pytest.raises(ValueError, match="release_golden"):
        kv.free_seq(sid)
    assert kv.demote_seq(sid) == 0               # golden layers stay hot
    check_kv_invariants(kv)
    # content addressing: an identical sequence hashes identically, a
    # different one doesn't
    twin, other = kv.new_seq(), kv.new_seq()
    kv.append_prefill(twin, k, v)
    kv.append_prefill(other, *rand_kv(8, 12))
    assert kv.register_golden(twin) == h
    assert kv.register_golden(other) != h
    kv.release_golden(sid)
    kv.free_seq(sid)                             # now an ordinary free
    check_kv_invariants(kv)


@pytest.mark.parametrize("scalable", [False, True])
def test_kv_fork_of_golden_decodes_on(scalable):
    kv = kv_cache(scalable)
    sid = kv.new_seq()
    kv.append_prefill(sid, *rand_kv(8, 13))
    kv.register_golden(sid)
    child = kv.fork(sid)
    k, v = rand_kv(2, 14)
    kv.append_prefill(child, k, v)               # the suffix
    gk, _ = kv.gather(child)
    pk, _ = kv.gather(sid)
    assert np.array_equal(np.asarray(gk[:, :8]), np.asarray(pk))
    st = kv.golden_stats()
    assert st["golden_seqs"] == 1
    assert st["golden_blocks_shared"] == 2       # 8 tokens / bs 4
    assert st["dedup_blocks_saved"] == 2
    check_kv_invariants(kv)


def test_kv_invariants_catch_golden_flag_drift():
    kv = kv_cache(True)
    sid = kv.new_seq()
    kv.append_prefill(sid, *rand_kv(4, 15))
    kv.register_golden(sid)
    del kv._golden[sid]                          # the deliberate drift
    with pytest.raises(AssertionError):
        check_kv_invariants(kv)


@pytest.mark.parametrize("scalable", [False, True])
def test_prepare_step_single_matches_batched(scalable):
    kv = kv_cache(scalable)
    a, b = kv.new_seq(), kv.new_seq()
    kv.append_prefill(a, *rand_kv(7, 16))
    kv.append_prefill(b, *rand_kv(5, 17))
    c = kv.fork(a)
    want_t, want_l = kv.prepare_step([c])
    # a fresh fork so the single-sequence path does its own COW prepare
    d = kv.fork(a)
    got_t, got_l = kv.prepare_step_single(d)
    assert got_t.shape == want_t.shape and got_l.shape == want_l.shape
    # same parent, same length: the write block differs (each fork COWs
    # its own), everything else must agree
    wt, gt = np.asarray(want_t)[0], np.asarray(got_t)[0]
    blk = int(np.asarray(want_l)[0]) // kv.cfg.block_size
    assert np.array_equal(np.delete(wt, blk), np.delete(gt, blk))
    assert np.array_equal(np.asarray(want_l), np.asarray(got_l))
    # and on the very same sequence the two paths are bit-identical
    t1, l1 = kv.prepare_step([c])
    t2, l2 = kv.prepare_step_single(c)
    assert np.array_equal(np.asarray(t1), np.asarray(t2))
    assert np.array_equal(np.asarray(l1), np.asarray(l2))


# -- serving plane: Engine admission -----------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    cfg = dataclasses.replace(smoke_config("qwen2-7b"), n_layers=1)
    return cfg, get_model(cfg).init(jax.random.PRNGKey(0))


def make_engine(tiny_model, scalable=True):
    cfg, params = tiny_model
    return Engine(cfg, params, scalable=scalable, n_blocks=256,
                  block_size=4, max_blocks_per_seq=32,
                  resolver="gather", decode_path="tables")


@pytest.mark.parametrize("scalable", [False, True])
def test_engine_admission_bitwise_vs_duplicate_storage(tiny_model,
                                                       scalable):
    """A prefix-hit admission must be bitwise what a dedup-free engine
    would store: duplicate the golden's bytes, run the SAME chunked
    suffix dispatch, compare everything."""
    eng = make_engine(tiny_model, scalable)
    rng = np.random.default_rng(18)
    prefix = rng.integers(0, eng.cfg.vocab_size, 24).tolist()
    suffix = rng.integers(0, eng.cfg.vocab_size, 3).tolist()
    gsid = eng.register_golden(np.asarray(prefix, np.int32))

    sid = eng.add_request(np.asarray(prefix + suffix, np.int32))
    assert eng.golden_hits == 1
    tok = eng.active[sid][0]

    gk, gv = eng.kv.gather(gsid)
    osid = eng.kv.new_seq()
    eng.kv.append_prefill(osid, gk, gv)          # duplicate the storage
    otok = eng._suffix_prefill(osid, suffix)     # the same dispatch
    assert tok == otok
    fk, fv = eng.kv.gather(sid)
    ok_, ov_ = eng.kv.gather(osid)
    assert np.array_equal(np.asarray(fk), np.asarray(ok_))
    assert np.array_equal(np.asarray(fv), np.asarray(ov_))
    check_kv_invariants(eng.kv)

    # the fork decodes on (COW write slots, frozen base untouched)
    eng.step()
    assert len(eng.active[sid]) == 2

    stats = eng.memory_stats()
    assert stats["golden_hits"] == 1
    assert stats["golden_seqs"] == 1
    assert stats["dedup_blocks_saved"] >= 6      # 24 tokens / bs 4


def test_engine_exact_match_skips_model(tiny_model):
    eng = make_engine(tiny_model)
    rng = np.random.default_rng(19)
    prompt = rng.integers(0, eng.cfg.vocab_size, 16).tolist()
    gsid = eng.register_golden(np.asarray(prompt, np.int32))
    before = eng.kv.blocks_in_use()
    sid = eng.add_request(np.asarray(prompt, np.int32))
    # an exact match forks and replays the recorded first token — the
    # only new block is the fork's COW copy of the partial tail block
    assert eng.active[sid][0] == eng._golden_info[gsid][1]
    assert eng.kv.blocks_in_use() <= before + 1
    assert eng.golden_hits == 1


def test_engine_miss_takes_full_prefill(tiny_model):
    eng = make_engine(tiny_model)
    rng = np.random.default_rng(20)
    eng.register_golden(
        np.asarray(rng.integers(0, eng.cfg.vocab_size, 16), np.int32))
    other = rng.integers(0, eng.cfg.vocab_size, 12)
    sid = eng.add_request(np.asarray(other, np.int32))
    assert eng.golden_hits == 0
    assert eng.kv.seq_length(sid) == 12
    eng.step()
    assert len(eng.active[sid]) == 2


def test_engine_release_golden_unfreezes(tiny_model):
    eng = make_engine(tiny_model)
    rng = np.random.default_rng(21)
    prompt = np.asarray(rng.integers(0, eng.cfg.vocab_size, 16), np.int32)
    gsid = eng.register_golden(prompt)
    sid = eng.add_request(np.asarray(
        prompt.tolist() + rng.integers(0, eng.cfg.vocab_size, 2).tolist(),
        np.int32))
    eng.release_golden(gsid)
    # the trie no longer matches: a new identical prompt full-prefills
    sid2 = eng.add_request(prompt)
    assert eng.golden_hits == 1                  # only the pre-release hit
    # the fork keeps decoding after its base was released (its blocks
    # are refcounted, not lifetime-coupled to the registration)
    eng.step()
    assert len(eng.active[sid]) == 2 and len(eng.active[sid2]) == 2
    check_kv_invariants(eng.kv)
