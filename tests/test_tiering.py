"""Tiered page pool: HBM <-> host spill for cold snapshot layers.

Contracts under test:

* ``fleet.demote_tenants`` -> ``fleet.promote_tenants`` round-trips
  bit-identically, including through COW writes to a descendant layer
  while an ancestor layer is cold (property-tested);
* the ``MaintenanceScheduler`` demotion policy never touches a tenant's
  active layer and never violates lease non-aliasing, no matter how its
  budgeted ticks interleave with serving writes;
* ``free_tenant``/``compact`` leave no orphaned host pages: a freed cold
  tenant returns its host rows to the ``TieredStore`` free list;
* the KV-cache/serving analogue (``PagedKVCache.demote_seq`` /
  ``promote_seq``) spills only provably-exclusive blocks and promotes
  lazily from every table-producing path.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet, format as fmt, metrics
from repro.core.scheduler import MaintenanceScheduler
from repro.core.store import TieredStore
from repro.core.invariants import check_fleet_invariants as check_lease_invariants

N_PAGES, PAGE = 32, 4


def make_fleet(n_tenants=3, *, scalable=True, max_chain=8,
               lease_quantum=8, pool_capacity=1024):
    spec = fleet.FleetSpec(
        n_tenants=n_tenants, n_pages=N_PAGES, page_size=PAGE,
        max_chain=max_chain, pool_capacity=pool_capacity,
        lease_quantum=lease_quantum, l2_per_table=N_PAGES,
    )
    return fleet.create(spec, scalable=jnp.asarray(scalable, bool))


def grow(fl, layers, *, writes=6, seed=0):
    rng = np.random.default_rng(seed)
    t = fl.spec.n_tenants
    for layer in range(layers):
        ids = np.stack([rng.choice(N_PAGES, writes, replace=False)
                        for _ in range(t)]).astype(np.int32)
        data = rng.standard_normal((t, writes, PAGE)).astype(np.float32)
        fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data))
        if layer < layers - 1:
            fl = fleet.snapshot(fl)
    return fl


def full_grid(fl):
    return jnp.broadcast_to(jnp.arange(N_PAGES, dtype=jnp.int32)[None],
                            (fl.spec.n_tenants, N_PAGES))


def snapshot_reads(fl, store=None):
    """(data, found&~zero) for the whole fleet, through the host tier."""
    if store is None:
        data, res = fleet.read(fl, full_grid(fl))
    else:
        data, res = fleet.read_tiered(fl, store, full_grid(fl))
    ok = np.asarray(res.found) & ~np.asarray(res.zero)
    return np.asarray(data), ok


def active_layer_never_cold(fl):
    """No entry *owned* by a tenant's active layer carries FLAG_COLD.

    Ownership is first-reference from the top: an active-layer entry
    whose row is also referenced below is an inherited copy-forward
    (allowed to be cold); a row owned by the active layer itself is the
    mutable working set and must stay hot.
    """
    l2 = np.asarray(fl.l2)
    for t in range(fl.spec.n_tenants):
        length = int(np.asarray(fl.length)[t])
        w0 = l2[t, :length, ..., 0]
        alloc = (w0 & np.uint32(fmt.FLAG_ALLOCATED)) != 0
        cold = (w0 & np.uint32(fmt.FLAG_COLD)) != 0
        rows = (w0 & np.uint32(fmt.PTR_MASK)).astype(np.int64)
        act = length - 1
        for p in np.flatnonzero(alloc[act] & cold[act]):
            below = alloc[:act, p] & (rows[:act, p] == rows[act, p])
            assert below.any(), \
                f"tenant {t}: active layer owns a cold row at page {p}"


@pytest.mark.parametrize("scalable", [True, False])
def test_demote_promote_roundtrip_bit_identical(scalable):
    fl = grow(make_fleet(scalable=scalable), layers=5, seed=1)
    store = TieredStore.for_fleet(fl.spec)
    before, okb = snapshot_reads(fl)

    fl, rep = fleet.demote_tenants(fl, store, [0, 2])
    assert rep["rows_demoted"] > 0 and sorted(rep["tenants"]) == [0, 2]
    check_lease_invariants(fl)
    active_layer_never_cold(fl)
    assert store.host_rows_in_use() == rep["rows_demoted"]
    st = fleet.fleet_stats(fl)
    assert st["cold_tenants"] == 2 and st["rows_cold"] == rep["rows_demoted"]

    # the device-only read masks cold pages; the tiered read serves them
    dev, _ = snapshot_reads(fl)
    cold = np.asarray(fleet.get_resolver("auto")(fl, full_grid(fl)).cold)
    assert cold[[0, 2]].any() and not cold[1].any()
    assert (dev[cold] == 0).all()
    tiered, okt = snapshot_reads(fl, store)
    np.testing.assert_array_equal(okt, okb)
    assert np.array_equal(tiered.view(np.uint8), before.view(np.uint8))

    fl, prep = fleet.promote_tenants(fl, store, [0, 2])
    assert prep["rows_promoted"] == rep["rows_demoted"]
    assert store.host_rows_in_use() == 0
    check_lease_invariants(fl)
    after, oka = snapshot_reads(fl)
    np.testing.assert_array_equal(oka, okb)
    assert np.array_equal(after.view(np.uint8), before.view(np.uint8))
    resid = metrics.tier_residency(fl, store)
    assert resid.host_rows == 0 and resid.cold_tenants == 0
    assert resid.demoted_rows == resid.promoted_rows > 0


@pytest.mark.parametrize("scalable", [True, False])
def test_cow_write_while_ancestor_cold(scalable):
    """COW writes land in the active layer while ancestor layers sit in
    the host tier; promotion afterwards restores a bit-exact view of the
    unwritten pages and keeps the new writes."""
    fl = grow(make_fleet(n_tenants=2, scalable=scalable), layers=4, seed=3)
    store = TieredStore.for_fleet(fl.spec)
    before, _ = snapshot_reads(fl)

    fl, rep = fleet.demote_tenants(fl, store, True)
    assert rep["rows_demoted"] > 0
    fl = fleet.snapshot(fl)          # fork a fresh descendant COW layer
    ids = np.asarray([[0, 1], [2, 3]], np.int32)
    data = np.full((2, 2, PAGE), 7.5, np.float32)
    fl = fleet.write(fl, jnp.asarray(ids), jnp.asarray(data))
    check_lease_invariants(fl)
    active_layer_never_cold(fl)

    fl, _ = fleet.promote_tenants(fl, store, True)
    assert store.host_rows_in_use() == 0
    after, ok = snapshot_reads(fl)
    expect = before.copy()
    for t in range(2):
        expect[t, ids[t]] = data[t]
    assert np.array_equal(after.view(np.uint8), expect.view(np.uint8))
    # the COW write itself must not have been spilled or masked
    assert ok[0, 0] and ok[1, 2]


def test_demote_roundtrip_property():
    """Hypothesis: arbitrary write/snapshot/demote/promote interleavings
    keep the tiered fleet bit-identical to an untiered twin."""
    pytest.importorskip("hypothesis",
                        reason="install extras: pip install -e .[test]")
    from hypothesis import given, settings, strategies as st

    op = st.one_of(
        st.tuples(st.just("write"),
                  st.lists(st.integers(0, N_PAGES - 1), min_size=1,
                           max_size=4, unique=True),
                  st.integers(0, 2**31 - 1)),
        st.tuples(st.just("snapshot"), st.just(None), st.just(None)),
        st.tuples(st.just("demote"), st.integers(0, 2), st.integers(1, 16)),
        st.tuples(st.just("promote"), st.integers(0, 2), st.just(None)),
    )

    @settings(deadline=None, max_examples=15)
    @given(st.lists(op, min_size=1, max_size=12), st.booleans())
    def run(ops, scalable):
        tiered = make_fleet(scalable=scalable, max_chain=16)
        plain = make_fleet(scalable=scalable, max_chain=16)
        store = TieredStore.for_fleet(tiered.spec)
        for kind, a, b in ops:
            if kind == "write":
                ids = np.broadcast_to(np.asarray(a, np.int32), (3, len(a)))
                rng = np.random.default_rng(b)
                data = rng.standard_normal((3, len(a), PAGE)) \
                    .astype(np.float32)
                tiered = fleet.write(tiered, jnp.asarray(ids),
                                     jnp.asarray(data))
                plain = fleet.write(plain, jnp.asarray(ids),
                                    jnp.asarray(data))
            elif kind == "snapshot":
                tiered = fleet.snapshot(tiered)
                plain = fleet.snapshot(plain)
            elif kind == "demote":
                tiered, _ = fleet.demote_tenants(tiered, store, [a],
                                                 max_rows=b)
            else:
                tiered, _ = fleet.promote_tenants(tiered, store, [a])
            check_lease_invariants(tiered)
            active_layer_never_cold(tiered)
        want, okw = snapshot_reads(plain)
        got, okg = snapshot_reads(tiered, store)
        np.testing.assert_array_equal(okg, okw)
        assert np.array_equal(got.view(np.uint8), want.view(np.uint8))
        # full promotion converges back to an all-device fleet
        tiered, _ = fleet.promote_tenants(tiered, store, True)
        assert store.host_rows_in_use() == 0
        got2, _ = snapshot_reads(tiered)
        assert np.array_equal(got2.view(np.uint8), want.view(np.uint8))

    run()


@pytest.mark.parametrize("scalable", [True, False])
def test_scheduler_demotion_interleaved_with_serving(scalable):
    """Budgeted demotion ticks interleaved with serving writes: the
    active layer is never spilled, leases never alias, the per-tick row
    cap holds, and the fleet converges to the device budget."""
    fl = make_fleet(n_tenants=4, scalable=scalable, max_chain=12,
                    pool_capacity=2048)
    store = TieredStore.for_fleet(fl.spec)
    sched = MaintenanceScheduler(
        fl, stream_chain_threshold=10**6,   # isolate the demotion policy
        store=store, device_page_budget=40, demote_rows_per_tick=7,
    )
    rng = np.random.default_rng(7)
    shadow, ok0 = None, None
    for step in range(30):
        ids = np.stack([rng.choice(N_PAGES, 4, replace=False)
                        for _ in range(4)]).astype(np.int32)
        data = rng.standard_normal((4, 4, PAGE)).astype(np.float32)
        sched.fleet = fleet.write(sched.fleet, jnp.asarray(ids),
                                  jnp.asarray(data))
        if step % 3 == 2 and step < 27:
            sched.fleet = fleet.snapshot(sched.fleet)
        rep = sched.tick()
        assert rep["rows_demoted"] <= 7
        check_lease_invariants(sched.fleet)
        active_layer_never_cold(sched.fleet)
    shadow, ok0 = snapshot_reads(sched.fleet, store)
    # drain: converge to the budget, then verify nothing was lost
    for _ in range(200):
        if sched._over_budget(fleet.tenant_stats(sched.fleet)) == 0:
            break
        if not sched.tick()["rows_demoted"]:
            break
    # converged: at budget, or every remaining row is an undemotable
    # active layer (the lease-quantum floor the policy must respect)
    st = fleet.tenant_stats(sched.fleet)
    assert (sched._over_budget(st) == 0
            or not sched._demote_candidates(st))
    assert int(np.sum(st["alloc_count"])) <= 40 + 4 * fl.spec.lease_quantum
    assert sched.rows_demoted == store.demoted_rows > 0
    got, ok1 = snapshot_reads(sched.fleet, store)
    np.testing.assert_array_equal(ok1, ok0)
    assert np.array_equal(got.view(np.uint8), shadow.view(np.uint8))
    assert sched.stats()["rows_demoted"] == sched.rows_demoted
    assert sched.stats()["host_rows_in_use"] == store.host_rows_in_use()


def test_free_tenant_returns_cold_rows():
    """Freeing a tenant with demoted pages must return its host rows to
    the TieredStore free list and clear its residency counters — no
    orphaned host pages (regression: free once only swept device rows)."""
    fl = grow(make_fleet(), layers=4, seed=5)
    store = TieredStore.for_fleet(fl.spec)
    fl, rep = fleet.demote_tenants(fl, store, [0, 1])
    held = store.host_rows_in_use()
    assert held == rep["rows_demoted"] > 0

    with pytest.raises(ValueError, match="host-tier rows"):
        fleet.free_tenant(fl, [0])       # cold tenant needs the store

    fl = fleet.free_tenant(fl, [0], store=store)
    assert int(np.asarray(fl.cold_count)[0]) == 0
    assert store.host_rows_in_use() < held
    check_lease_invariants(fl)
    fl = fleet.free_tenant(fl, [1], store=store)
    assert store.host_rows_in_use() == 0
    assert fleet.fleet_stats(fl)["cold_tenants"] == 0
    # freed host rows are recycled, not leaked: demoting again reuses them
    fl = grow(fl, layers=3, seed=6)
    fl, rep2 = fleet.demote_tenants(fl, store, True)
    assert store.host_rows_in_use() == rep2["rows_demoted"]
    assert store.stats()["host_rows_capacity"] >= store.host_rows_in_use()


def test_compact_preserves_cold_entries():
    """A pool repack moves device rows only: cold entries keep their host
    row ptrs, and the tiered read is unchanged."""
    fl = grow(make_fleet(), layers=4, seed=8)
    store = TieredStore.for_fleet(fl.spec)
    fl, _ = fleet.demote_tenants(fl, store, [1])
    before, ok0 = snapshot_reads(fl, store)
    fl = fleet.compact(fl)
    check_lease_invariants(fl)
    after, ok1 = snapshot_reads(fl, store)
    np.testing.assert_array_equal(ok1, ok0)
    assert np.array_equal(after.view(np.uint8), before.view(np.uint8))
    assert store.host_rows_in_use() > 0   # compact must not drop the tier


def test_clone_refuses_cold_source():
    fl = grow(make_fleet(), layers=3, seed=9)
    store = TieredStore.for_fleet(fl.spec)
    fl, _ = fleet.demote_tenants(fl, store, [0])
    with pytest.raises(ValueError, match="cold"):
        fleet.clone_tenant(fl, 0, 2)


def test_tiered_pool_bytes_model():
    spec = make_fleet().spec
    all_hbm = metrics.tiered_pool_bytes(spec, 500, 8, tiered=False)
    tiered = metrics.tiered_pool_bytes(spec, 500, 8, tiered=True)
    assert all_hbm == 500 * tiered
    assert tiered == 8 * PAGE * 4


# -- serving plane: PagedKVCache spill ---------------------------------------


def _kv_cfg():
    from repro.kvcache.paged import PagedKVConfig

    return PagedKVConfig(n_layers=2, n_kv_heads=2, head_dim=4, block_size=4,
                         n_blocks=64, max_blocks_per_seq=8,
                         dtype=jnp.float32)


def _tok(i, t):
    k = jnp.full((2, 2, 4), i * 100 + t, jnp.float32)
    return k, -k


@pytest.mark.parametrize("scalable", [True, False])
def test_kv_demote_promote_roundtrip(scalable):
    from repro.kvcache.paged import PagedKVCache

    kv = PagedKVCache(_kv_cfg(), scalable=scalable)
    a = kv.new_seq()
    for t in range(10):
        kv.append(a, *_tok(1, t))
    ka, va = np.asarray(kv.gather(a)[0]), np.asarray(kv.gather(a)[1])
    used = kv.blocks_in_use()

    n = kv.demote_seq(a)
    assert n == 2                      # two frozen blocks; the tail stays
    assert kv.blocks_in_use() == used - n
    assert kv.host_blocks_in_use() == n
    # gather reads through the host tier without promoting
    k2, v2 = kv.gather(a)
    assert np.array_equal(np.asarray(k2), ka)
    assert np.array_equal(np.asarray(v2), va)
    assert kv.host_blocks_in_use() == n

    # any table-producing path promotes lazily and restores bit-identity
    kv.block_table(a)
    assert kv.host_blocks_in_use() == 0 and not kv._seqs[a].cold
    k3, v3 = kv.gather(a)
    assert np.array_equal(np.asarray(k3), ka)
    assert np.array_equal(np.asarray(v3), va)
    assert kv.promoted_blocks == kv.demoted_blocks == n


@pytest.mark.parametrize("scalable", [True, False])
def test_kv_shared_blocks_never_spill(scalable):
    """Blocks visible to a fork (refcounted or via vanilla layer copies)
    are not exclusive and must not demote; freeing the fork unlocks
    them."""
    from repro.kvcache.paged import PagedKVCache

    kv = PagedKVCache(_kv_cfg(), scalable=scalable)
    a = kv.new_seq()
    for t in range(10):
        kv.append(a, *_tok(1, t))
    c = kv.fork(a)
    assert kv.demote_seq(a) == 0       # everything shared with the fork
    for t in range(6):
        kv.append(c, *_tok(2, t))      # COW: c now owns exclusive blocks
    assert kv.demote_seq(c) >= 1
    kc = np.asarray(kv.gather(c)[0])
    kv.free_seq(c)                     # drops c's host spill with it
    assert kv.host_blocks_in_use() == 0
    assert kv.demote_seq(a) == 2       # fork gone -> a's blocks exclusive
    ka = np.asarray(kv.gather(a)[0])
    d = kv.fork(a)                     # fork auto-promotes the parent
    assert not kv._seqs[a].cold and kv.host_blocks_in_use() == 0
    assert np.array_equal(np.asarray(kv.gather(d)[0]), ka)
    del kc


def test_kv_parked_seq_survives_batch_decodes():
    from repro.kvcache.paged import PagedKVCache

    kv = PagedKVCache(_kv_cfg(), scalable=True)
    a, b = kv.new_seq(), kv.new_seq()
    for t in range(9):
        kv.append(a, *_tok(1, t))
        kv.append(b, *_tok(2, t))
    ka = np.asarray(kv.gather(a)[0])
    n = kv.demote_seq(a)
    assert n == 2
    pad = kv.reserve_block()
    for _ in range(3):                 # a parked, b decoding
        kv.prepare_step([b], pad_to=2, pad_block=pad)
        kv.advance(b)
    assert kv._seqs[a].cold and kv.host_blocks_in_use() == n
    kv.prepare_step([a, b], pad_to=2, pad_block=pad)   # a resumes
    kv.advance(a)
    kv.advance(b)
    assert kv.host_blocks_in_use() == 0
    assert np.array_equal(np.asarray(kv.gather(a)[0])[:, :9], ka)
