"""Tenant and sequence migration: export/detach/attach round-trips must
be bit-identical across every resolver, chain depths from 1 to 500,
demoted (cold) layers, different destination geometry, and the serving
plane's fork/tombstone topology.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fleet as fleet_lib
from repro.core import migrate
from repro.core.invariants import check_fleet_invariants, check_kv_invariants
from repro.core.store import TieredStore
from repro.kvcache.paged import PagedKVCache, PagedKVConfig

RESOLVERS = ["vanilla", "direct", "auto", "pallas_vanilla", "pallas_direct"]

N_PAGES = 32
PAGE = 4


def _spec(**kw):
    base = dict(n_tenants=3, n_pages=N_PAGES, page_size=PAGE, max_chain=8,
                pool_capacity=4096, lease_quantum=8, l2_per_table=N_PAGES)
    base.update(kw)
    return fleet_lib.FleetSpec(**base)


def _grow(fl, rng, *, layers, writes_per_layer=2, batch=2):
    """Random COW churn: ``layers - 1`` snapshots with writes between."""
    spec = fl.spec
    for layer in range(layers):
        if layer:
            fl = fleet_lib.snapshot(fl)
        for _ in range(writes_per_layer):
            ids = np.stack([
                rng.choice(spec.n_pages, batch, replace=False)
                for _ in range(spec.n_tenants)
            ]).astype(np.int32)
            data = rng.standard_normal(
                (spec.n_tenants, batch, spec.page_size)
            ).astype(np.float32)
            fl = fleet_lib.write(fl, jnp.asarray(ids), jnp.asarray(data))
    assert not np.asarray(fl.overflow).any()
    return fl


def _dst_fleet(depth):
    """A destination with different tenant count, pool capacity, lease
    quantum, spare chain depth and default format flag."""
    spec = _spec(n_tenants=2, pool_capacity=8192, lease_quantum=16,
                 max_chain=depth + 2)
    return fleet_lib.create(spec, scalable=False), TieredStore.for_fleet(spec)


@pytest.fixture(scope="module", params=[1, 64, 500])
def grown(request):
    """One grown source fleet per depth, shared by the resolver matrix
    (depth 500 builds a genuinely 500-layer chain — growing it once,
    not once per resolver, keeps the matrix tractable)."""
    depth = request.param
    rng = np.random.default_rng(depth)
    spec = _spec(max_chain=depth + 1)
    fl = fleet_lib.create(spec, scalable=True)
    fl = _grow(fl, rng, layers=depth,
               writes_per_layer=2 if depth < 500 else 1)
    store = TieredStore.for_fleet(spec)
    # tenant 1 carries demoted (cold) layers through every round-trip
    fl, rep = fleet_lib.demote_tenants(fl, store, [1], max_rows=24)
    if depth > 1:
        assert rep["rows_demoted"] > 0
    check_fleet_invariants(fl, store=store)
    return depth, fl, store


def _own_store(grown):
    """The fleet value is functional, but the ``TieredStore`` is mutable
    host state: tests that detach (freeing host rows) get a private
    copy so the module-scoped fixture stays pristine."""
    depth, fl, store = grown
    return depth, fl, store.clone()


@pytest.mark.parametrize("method", RESOLVERS)
def test_round_trip_bit_identical(grown, method):
    """read/read_tiered before == after for every resolver × depth,
    into a different-geometry fleet, cold layers included."""
    depth, fl, store = _own_store(grown)
    dst, dst_store = _dst_fleet(depth)
    for t_src, t_dst in [(0, 1), (1, 0)]:       # t=1 holds cold layers
        before = migrate.materialize_tenant(fl, t_src, store=store,
                                            method=method)
        src2, dst, report = migrate.migrate_tenant(
            fl, t_src, dst, t_dst, src_store=store, dst_store=dst_store,
            method=method,
        )
        after = migrate.materialize_tenant(dst, t_dst, store=dst_store,
                                           method=method)
        assert (before == after).all()
        assert report["length"] == depth and report["verified"]
        # plain read must agree wherever the destination copy is hot
        grid = np.broadcast_to(np.arange(N_PAGES, dtype=np.int32),
                               (dst.spec.n_tenants, N_PAGES))
        data, res = fleet_lib.read(dst, jnp.asarray(grid), method=method)
        hot = ~np.asarray(res.cold)[t_dst]
        assert (np.asarray(data)[t_dst][hot] == after[hot]).all()
        check_fleet_invariants(src2, store=store)
        check_fleet_invariants(dst, store=dst_store)
        if t_src == 1:
            assert report["rows_cold"] == (0 if depth == 1 else
                                           int(dst.cold_count[t_dst]))


def test_detached_source_slot_is_clean(grown):
    depth, fl, store = _own_store(grown)
    dst, dst_store = _dst_fleet(depth)
    host_before = store.host_rows_in_use()
    cold_held = int(fl.cold_count[1])
    fl2, dst, _ = migrate.migrate_tenant(fl, 1, dst, 0,
                                         src_store=store,
                                         dst_store=dst_store)
    assert int(fl2.length[1]) == 1
    assert int(fl2.lease_count[1]) == 0
    assert int(fl2.cold_count[1]) == 0
    # the source's cold rows went back to ITS store; the copies live in
    # the destination's store now
    assert store.host_rows_in_use() == host_before - cold_held
    assert dst_store.host_rows_in_use() == cold_held
    check_fleet_invariants(fl2, store=store)


def test_mid_migration_write_guard(grown):
    """A write landing between export and detach must make the detach
    refuse — and leave the source fully intact."""
    depth, fl, store = _own_store(grown)
    blob = migrate.export_tenant(fl, 0, store=store)
    ids = np.zeros((fl.spec.n_tenants, 1), np.int32)
    data = np.ones((fl.spec.n_tenants, 1, PAGE), np.float32)
    mask = np.zeros(fl.spec.n_tenants, bool)
    mask[0] = True
    fl2 = fleet_lib.write(fl, jnp.asarray(ids), jnp.asarray(data),
                          jnp.asarray(mask))
    with pytest.raises(migrate.MigrationError):
        migrate.detach_tenant(fl2, 0, blob, store=store)
    # un-written tenants detach fine with their own (fresh) blob
    blob1 = migrate.export_tenant(fl2, 1, store=store)
    fl3 = migrate.detach_tenant(fl2, 1, blob1, store=store)
    check_fleet_invariants(fl3, store=store)


def test_maintenance_after_export_is_also_stale(grown):
    """Streaming rewrites pointers without changing data; the guard is
    deliberately conservative and treats that as staleness too."""
    depth, fl, store = _own_store(grown)
    if depth == 1:
        pytest.skip("a length-1 chain has nothing to stream")
    blob = migrate.export_tenant(fl, 0, store=store)
    fl2 = fleet_lib.stream_tenants(fl, np.asarray([True, False, False]),
                                   depth - 2)
    if migrate.tenant_fingerprint(fl2, 0) != blob.fingerprint:
        with pytest.raises(migrate.MigrationError):
            migrate.detach_tenant(fl2, 0, blob, store=store)


def test_blob_disk_round_trip(grown, tmp_path):
    depth, fl, store = grown
    blob = migrate.export_tenant(fl, 1, store=store)
    path = tmp_path / "tenant1.npz"
    migrate.save_blob(blob, path)
    loaded = migrate.load_blob(path)
    assert loaded.fingerprint == blob.fingerprint
    assert loaded.length == blob.length and loaded.scalable == blob.scalable
    for field in ("l1", "l2", "hot_pages", "cold_pages"):
        assert (getattr(loaded, field) == getattr(blob, field)).all()
    dst, dst_store = _dst_fleet(depth)
    dst = migrate.import_tenant(dst, 1, loaded, store=dst_store)
    assert (migrate.materialize_tenant(fl, 1, store=store)
            == migrate.materialize_tenant(dst, 1, store=dst_store)).all()


def test_checkpoint_tenant_dir_round_trip(grown, tmp_path):
    """The checkpoint plane's per-tenant durability rides the migration
    blob: save into a directory, restore into a different-geometry
    fleet (trainer-restart path for one fleet tenant)."""
    from repro.checkpoint import snapstore_ckpt

    depth, fl, store = grown
    snapstore_ckpt.save_tenant_to_dir(fl, 1, str(tmp_path), store=store)
    dst, dst_store = _dst_fleet(depth)
    dst = snapstore_ckpt.load_tenant_from_dir(dst, 0, str(tmp_path),
                                              src_tenant=1, store=dst_store)
    assert (migrate.materialize_tenant(fl, 1, store=store)
            == migrate.materialize_tenant(dst, 0, store=dst_store)).all()
    check_fleet_invariants(dst, store=dst_store)


def test_import_refuses_geometry_mismatch():
    rng = np.random.default_rng(0)
    fl = _grow(fleet_lib.create(_spec(), scalable=True), rng, layers=2)
    blob = migrate.export_tenant(fl, 0)
    bad = fleet_lib.create(
        fleet_lib.FleetSpec(n_tenants=2, n_pages=2 * N_PAGES, page_size=PAGE,
                            max_chain=8, pool_capacity=4096, lease_quantum=8,
                            l2_per_table=2 * N_PAGES))
    with pytest.raises(migrate.MigrationError, match="n_pages"):
        migrate.import_tenant(bad, 0, blob)
    shallow = fleet_lib.create(_spec(max_chain=blob.length))
    # max_chain == length fits exactly; one less must refuse
    migrate.import_tenant(shallow, 0, blob)
    if blob.length > 1:
        too_shallow = fleet_lib.create(_spec(max_chain=blob.length - 1))
        with pytest.raises(migrate.MigrationError, match="max_chain"):
            migrate.import_tenant(too_shallow, 0, blob)


def test_import_evicts_previous_occupant():
    """Landing a migrant in an occupied slot resets it first — leases
    and host rows of the evictee are returned, not leaked."""
    rng = np.random.default_rng(1)
    fl = _grow(fleet_lib.create(_spec(), scalable=True), rng, layers=3)
    store = TieredStore.for_fleet(fl.spec)
    fl, _ = fleet_lib.demote_tenants(fl, store, [2], max_rows=8)
    dst, dst_store = _dst_fleet(3)
    dst = migrate.import_tenant(
        dst, 0, migrate.export_tenant(fl, 2, store=store), store=dst_store)
    occupied_host = dst_store.host_rows_in_use()
    dst = migrate.import_tenant(
        dst, 0, migrate.export_tenant(fl, 0, store=store), store=dst_store)
    assert dst_store.host_rows_in_use() < occupied_host or occupied_host == 0
    assert (migrate.materialize_tenant(fl, 0, store=store)
            == migrate.materialize_tenant(dst, 0, store=dst_store)).all()
    check_fleet_invariants(dst, store=dst_store)


# -- serving plane: sequence migration between caches/engines ----------------


KV = PagedKVConfig(n_layers=2, n_kv_heads=1, head_dim=4, block_size=4,
                   n_blocks=64, max_blocks_per_seq=8, dtype=jnp.float32)
KV_DST = PagedKVConfig(n_layers=2, n_kv_heads=1, head_dim=4, block_size=8,
                       n_blocks=32, max_blocks_per_seq=8, dtype=jnp.float32)


def _toks(rng, n):
    shape = (2, n, 1, 4)
    return (jnp.asarray(rng.standard_normal(shape), jnp.float32),
            jnp.asarray(rng.standard_normal(shape), jnp.float32))


def test_seq_migration_with_tombstoned_ancestor():
    """Migrate a forked child while its freed parent is a tombstone; the
    source-side free after migration must reap the whole dead chain."""
    rng = np.random.default_rng(2)
    src = PagedKVCache(KV, scalable=False)   # vanilla: real parent links
    dst = PagedKVCache(KV_DST, scalable=True)

    root = src.new_seq()
    k, v = _toks(rng, 10)
    src.append_prefill(root, k, v)
    child = src.fork(root)
    k2, v2 = _toks(rng, 5)
    src.append_prefill(child, k2, v2)
    src.free_seq(root)
    assert src._seqs[root].freed          # tombstoned, pinned by child
    check_kv_invariants(src)

    want_k, want_v = src.gather(child)
    blob = src.export_seq(child)
    new_sid = dst.import_seq(blob)
    got_k, got_v = dst.gather(new_sid)
    assert (np.asarray(got_k) == np.asarray(want_k)).all()
    assert (np.asarray(got_v) == np.asarray(want_v)).all()

    src.free_seq(child)                   # detach: cascade reaps the chain
    assert root not in src._seqs and child not in src._seqs
    assert src.blocks_in_use() == 0
    check_kv_invariants(src)
    check_kv_invariants(dst)


def test_seq_migration_of_spilled_sequence():
    """A parked (host-spilled) sequence migrates without being promoted
    on the source."""
    rng = np.random.default_rng(3)
    src = PagedKVCache(KV, scalable=False)
    dst = PagedKVCache(KV_DST, scalable=True)
    sid = src.new_seq()
    k, v = _toks(rng, 9)
    src.append_prefill(sid, k, v)
    spilled = src.demote_seq(sid)
    assert spilled > 0
    host_before = src.host_blocks_in_use()
    blob = src.export_seq(sid)
    assert src.host_blocks_in_use() == host_before   # residency untouched
    new_sid = dst.import_seq(blob)
    gk, gv = dst.gather(new_sid)
    assert (np.asarray(gk) == blob["k"]).all()
    assert (np.asarray(gv) == blob["v"]).all()
    check_kv_invariants(src)
    check_kv_invariants(dst)


def test_engine_migration_decode_parity():
    """A request migrated between engines (different block size, pool
    size and format) keeps decoding exactly as an unmigrated reference."""
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.serve.engine import Engine

    key = jax.random.PRNGKey(0)
    cfg = smoke_config("qwen2-7b")
    params = get_model(cfg).init(key)
    prompt = np.asarray(jax.random.randint(key, (9,), 0, cfg.vocab_size))

    src = Engine(cfg, params, scalable=False, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)
    dst = Engine(cfg, params, scalable=True, n_blocks=96, block_size=8,
                 max_blocks_per_seq=8)
    ref = Engine(cfg, params, scalable=True, n_blocks=64, block_size=4,
                 max_blocks_per_seq=16)

    a = src.add_request(prompt)
    r = ref.add_request(prompt)
    outs_a = [src.step() for _ in range(2)]
    outs_r = [ref.step() for _ in range(2)]
    assert [o[a] for o in outs_a] == [o[r] for o in outs_r]

    b = src.fork_request(a)
    src.finish_request(a)                   # tombstone the parent
    new = src.migrate_request_to(dst, b)
    assert not src.active and new in dst.active
    check_kv_invariants(src.kv)
    check_kv_invariants(dst.kv)

    outs_d = [dst.step() for _ in range(3)]
    outs_r2 = [ref.step() for _ in range(3)]
    assert [o[new] for o in outs_d] == [o[r] for o in outs_r2]

    # decode landing mid-migration flips the fingerprint guard
    c = src.add_request(prompt)
    blob = src.kv.export_seq(c)
    src.step()
    assert src.kv.seq_fingerprint(c) != blob["fingerprint"]


def test_import_seq_refuses_model_geometry_mismatch():
    rng = np.random.default_rng(4)
    src = PagedKVCache(KV, scalable=True)
    sid = src.new_seq()
    k, v = _toks(rng, 4)
    src.append_prefill(sid, k, v)
    blob = src.export_seq(sid)
    bad = PagedKVCache(
        PagedKVConfig(n_layers=3, n_kv_heads=1, head_dim=4, block_size=4,
                      n_blocks=16, max_blocks_per_seq=4,
                      dtype=jnp.float32))
    with pytest.raises(ValueError, match="n_layers"):
        bad.import_seq(blob)
    tiny = PagedKVCache(
        PagedKVConfig(n_layers=2, n_kv_heads=1, head_dim=4, block_size=4,
                      n_blocks=16, max_blocks_per_seq=1,
                      dtype=jnp.float32))
    with pytest.raises(ValueError, match="max_blocks_per_seq"):
        tiny.import_seq(
            {**blob, "length": 5,
             "k": np.zeros((2, 5, 1, 4), np.float32),
             "v": np.zeros((2, 5, 1, 4), np.float32)})
