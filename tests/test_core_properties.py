"""Property-based tests (hypothesis) for the snapshot store's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install extras: pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import cache, store

N_PAGES, PAGE, MAXC = 64, 4, 12

settings.register_profile("repro", deadline=None, max_examples=25)
settings.load_profile("repro")


def _ops_strategy():
    write_op = st.tuples(
        st.just("write"),
        st.lists(st.integers(0, N_PAGES - 1), min_size=1, max_size=8,
                 unique=True),
        st.integers(0, 2**31 - 1),
    )
    snap_op = st.tuples(st.just("snapshot"), st.just(None), st.just(None))
    return st.lists(st.one_of(write_op, snap_op), min_size=1, max_size=10)


def _apply_ops(ops, *, scalable):
    ch = store.create(n_pages=N_PAGES, page_size=PAGE, max_chain=MAXC,
                      scalable=scalable, pool_capacity=N_PAGES * 16)
    model = {}  # python reference: page -> np row
    snaps = 1
    for kind, ids, seed in ops:
        if kind == "snapshot":
            if snaps >= MAXC:
                continue
            ch = store.snapshot(ch)
            snaps += 1
        else:
            rng = np.random.default_rng(seed)
            data = rng.standard_normal((len(ids), PAGE)).astype(np.float32)
            ch = store.write(ch, jnp.asarray(ids, jnp.int32),
                             jnp.asarray(data))
            for j, p in enumerate(ids):
                model[p] = data[j]
    return ch, model


@given(_ops_strategy())
def test_read_matches_reference_model(ops):
    """COW read-your-writes across arbitrary write/snapshot interleavings."""
    ch, model = _apply_ops(ops, scalable=True)
    full = np.asarray(store.materialize(ch))
    for p in range(N_PAGES):
        expect = model.get(p, np.zeros(PAGE, np.float32))
        np.testing.assert_allclose(full[p], expect, rtol=1e-6,
                                   err_msg=f"page {p}")


@given(_ops_strategy())
def test_vanilla_direct_equivalence(ops):
    """sQEMU direct access returns exactly what the chain walk returns."""
    ch, _ = _apply_ops(ops, scalable=True)
    v = np.asarray(store.materialize(ch, method="vanilla"))
    d = np.asarray(store.materialize(ch, method="direct"))
    np.testing.assert_allclose(v, d, rtol=0, atol=0)


@given(_ops_strategy())
def test_backward_compat_auto_on_vanilla_format(ops):
    """A scalable reader (auto) on a vanilla-format image must fall back."""
    ch, model = _apply_ops(ops, scalable=False)
    a = np.asarray(store.materialize(ch, method="auto"))
    for p in range(N_PAGES):
        expect = model.get(p, np.zeros(PAGE, np.float32))
        np.testing.assert_allclose(a[p], expect, rtol=1e-6)


@given(_ops_strategy(), st.integers(0, 5))
def test_streaming_preserves_reads(ops, merge_upto):
    ch, model = _apply_ops(ops, scalable=True)
    length = int(ch.length)
    if merge_upto >= length - 1:
        merge_upto = max(0, length - 2)
    if merge_upto < 1:
        return
    ch2 = store.stream(ch, merge_upto=merge_upto, copy_data=False)
    full = np.asarray(store.materialize(ch2))
    for p in range(N_PAGES):
        expect = model.get(p, np.zeros(PAGE, np.float32))
        np.testing.assert_allclose(full[p], expect, rtol=1e-6)


@given(st.integers(0, 2**31 - 1))
def test_cache_correction_idempotent_and_monotone(seed):
    from repro.core import format as fmt

    rng = np.random.default_rng(seed)
    n = 16

    def rand_slice():
        return fmt.pack_entry(
            jnp.asarray(rng.integers(0, 1000, n), jnp.uint32),
            jnp.asarray(rng.integers(0, 8, n), jnp.uint32),
            allocated=jnp.asarray(rng.random(n) < 0.7),
            bfi_valid=True,
        )

    sv, sb = rand_slice(), rand_slice()
    once = cache.cache_correction(sv, sb)
    twice = cache.cache_correction(once, sb)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
    # monotone: the merged entry's bfi is never lower than sv's where sv
    # was allocated and the merge replaced it
    from repro.core.format import entry_allocated, entry_bfi

    sv_alloc = np.asarray(entry_allocated(sv))
    merged_bfi = np.asarray(entry_bfi(once))
    sv_bfi = np.asarray(entry_bfi(sv))
    assert np.all(merged_bfi[sv_alloc] >= sv_bfi[sv_alloc])
