import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run slow-marked tests too (full suite)")


def pytest_collection_modifyitems(config, items):
    """Skip slow tests by default, but never override an explicit choice:
    a -m marker expression, --runslow, or selection by node id all run
    exactly what was asked for."""
    if config.option.markexpr or config.getoption("--runslow"):
        return
    if any("::" in a for a in config.args):
        return
    skip = pytest.mark.skip(
        reason="slow: pass --runslow (or -m slow), or select by node id"
    )
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
