"""Seeded chaos/scenario harness: randomized operational churn with the
shared invariant suite checked after *every* event.

The clonebox idea made executable: one harness drives both planes of the
system through the kinds of storms a provider fleet actually sees —

* **fleet plane** (``core.fleet`` + ``core.store`` + the maintenance
  scheduler): COW write bursts, snapshot (deep-chain) churn, streaming,
  compaction, scheduler ticks, demote/promote races, tenant free/attach
  cycles, lease exhaustion, live migration to a second fleet with
  different geometry, writes landing mid-migration (the detach guard
  must fire), and golden-chain churn — register/fork/release against a
  ``GoldenRegistry`` threaded through every maintenance op, so frozen
  bases stay bit-stable under compaction, streaming and demotion while
  forks alias their rows;
* **serving plane** (``kvcache.paged``): fork storms, append bursts,
  tombstone cascades (freeing forked ancestors), park/resume (host
  spill + promotion), sequence migration between two caches with
  different block size/pool/format, decode steps landing mid-migration,
  and golden-prefix churn — register (freeze), prefix-hit admission
  (fork + suffix append) and release of shared-prefix bases.

After each event ``repro.core.invariants`` runs over every fleet, store
and cache involved, and an *independent* host-side data oracle — page
contents tracked event by event in plain dicts, never read back from the
system under test — is compared bit-for-bit against ``read_tiered`` /
``gather`` on a fixed cadence and at the end of the run.

Determinism: all randomness flows from one ``numpy`` generator seeded by
``ScenarioConfig.seed``, and every event appends a plain-primitive record
to ``trace`` — same seed, same config ⇒ byte-identical trace (the replay
self-test in ``test_scenarios.py`` holds the harness to this).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import fleet as fleet_lib
from repro.core import migrate
from repro.core import store as store_lib
from repro.core.golden import GoldenRegistry
from repro.core.invariants import (
    check_fleet_invariants,
    check_kv_invariants,
)
from repro.core.scheduler import MaintenanceScheduler
from repro.kvcache.paged import PagedKVCache, PagedKVConfig


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    seed: int = 0
    events: int = 200
    #: full data-oracle comparison cadence (invariants run every event)
    check_data_every: int = 10

    # source fleet geometry
    n_tenants: int = 4
    n_pages: int = 32
    page_size: int = 4
    max_chain: int = 6
    pool_capacity: int = 384
    lease_quantum: int = 8

    # destination fleet: deliberately different geometry & lease state
    dst_tenants: int = 3
    dst_max_chain: int = 8
    dst_pool_capacity: int = 512
    dst_lease_quantum: int = 16

    # serving plane (model geometry shared; block/pool/format differ)
    kv_layers: int = 1
    kv_heads: int = 1
    kv_head_dim: int = 4
    kv_blocks: int = 96
    kv_block_size: int = 4
    kv_dst_blocks: int = 64
    kv_dst_block_size: int = 8
    kv_max_blocks: int = 8

    write_batch: int = 2     # fixed (T, B) write shape: one jit trace


class ScenarioHarness:
    """One randomized run. ``run()`` fires ``config.events`` events and
    returns the trace; any invariant violation or oracle mismatch raises
    ``AssertionError`` at the event that caused it."""

    def __init__(self, config: ScenarioConfig):
        self.config = config
        self.rng = np.random.default_rng(config.seed)
        c = config

        spec = fleet_lib.FleetSpec(
            n_tenants=c.n_tenants, n_pages=c.n_pages, page_size=c.page_size,
            max_chain=c.max_chain, pool_capacity=c.pool_capacity,
            lease_quantum=c.lease_quantum, l2_per_table=c.n_pages,
        )
        self.store = store_lib.TieredStore.for_fleet(spec)
        self.registry = GoldenRegistry()
        self.sched = MaintenanceScheduler(
            fleet_lib.create(spec, scalable=True),
            max_tenants_per_tick=2, store=self.store,
            device_page_budget=c.pool_capacity // 2,
            demote_rows_per_tick=16, registry=self.registry,
        )
        dst_spec = fleet_lib.FleetSpec(
            n_tenants=c.dst_tenants, n_pages=c.n_pages,
            page_size=c.page_size, max_chain=c.dst_max_chain,
            pool_capacity=c.dst_pool_capacity,
            lease_quantum=c.dst_lease_quantum, l2_per_table=c.n_pages,
        )
        self.dst_fleet = fleet_lib.create(dst_spec, scalable=False)
        self.dst_store = store_lib.TieredStore.for_fleet(dst_spec)

        kv_cfg = PagedKVConfig(
            n_layers=c.kv_layers, n_kv_heads=c.kv_heads,
            head_dim=c.kv_head_dim, block_size=c.kv_block_size,
            n_blocks=c.kv_blocks, max_blocks_per_seq=c.kv_max_blocks,
            dtype=jnp.float32,
        )
        kv_dst_cfg = dataclasses.replace(
            kv_cfg, block_size=c.kv_dst_block_size, n_blocks=c.kv_dst_blocks,
        )
        # vanilla source: forks keep parent links, so freeing ancestors
        # exercises real tombstone cascades; scalable destination
        self.kv = PagedKVCache(kv_cfg, scalable=False)
        self.kv_dst = PagedKVCache(kv_dst_cfg, scalable=True)

        # independent oracles, maintained event by event
        self.expected: dict[int, dict[int, np.ndarray]] = {
            t: {} for t in range(c.n_tenants)
        }
        self.dst_expected: dict[int, dict[int, np.ndarray]] = {
            t: {} for t in range(c.dst_tenants)
        }
        # sid -> (k, v) numpy (L, length, H, D), per cache
        self.kv_expected: dict[int, tuple] = {}
        self.kv_dst_expected: dict[int, tuple] = {}
        self.kv_parked: set[int] = set()
        self.kv_golden: set[int] = set()

        self.trace: list[tuple] = []
        self.invariant_checks = 0
        self.guard_hits = 0        # mid-migration guards that fired
        self._step = 0

        self._events = [
            (self.ev_write, 5),
            (self.ev_snapshot, 3),
            (self.ev_stream, 2),
            (self.ev_compact, 1),
            (self.ev_tick, 2),
            (self.ev_demote, 2),
            (self.ev_promote, 1),
            (self.ev_free_attach, 1),
            (self.ev_migrate, 2),
            (self.ev_mid_migration_write, 1),
            (self.ev_golden_register, 1),
            (self.ev_golden_fork, 2),
            (self.ev_golden_release, 1),
            (self.ev_kv_new, 2),
            (self.ev_kv_append, 5),
            (self.ev_kv_fork_storm, 2),
            (self.ev_kv_free, 2),
            (self.ev_kv_park, 1),
            (self.ev_kv_resume, 1),
            (self.ev_kv_migrate, 2),
            (self.ev_kv_mid_migration, 1),
            (self.ev_kv_golden_register, 1),
            (self.ev_kv_golden_admit, 2),
            (self.ev_kv_golden_release, 1),
        ]
        w = np.asarray([wt for _, wt in self._events], np.float64)
        self._weights = w / w.sum()

    # -- fleet-plane events ---------------------------------------------------

    @property
    def fleet(self):
        return self.sched.fleet

    @fleet.setter
    def fleet(self, value):
        self.sched.fleet = value

    def _pick_tenant(self) -> int:
        return int(self.rng.integers(self.config.n_tenants))

    def _owner_mask(self) -> np.ndarray:
        return self.registry.golden_owner_mask(self.config.n_tenants)

    def ev_write(self):
        """COW write burst; partially-applied batches (lease exhaustion)
        reconcile the oracle against how many rows actually landed.
        Registered golden owners are content-frozen and never written;
        forks ARE written — their active volume overlays the shared base."""
        c = self.config
        tmask = (self.rng.random(c.n_tenants) < 0.7) & ~self._owner_mask()
        if not tmask.any():
            writable = np.flatnonzero(~self._owner_mask())
            tmask[int(self.rng.choice(writable))] = True
        ids = np.stack([
            self.rng.choice(c.n_pages, c.write_batch, replace=False)
            for _ in range(c.n_tenants)
        ]).astype(np.int32)
        data = self.rng.standard_normal(
            (c.n_tenants, c.write_batch, c.page_size)
        ).astype(np.float32)
        before = np.asarray(self.fleet.alloc_count)
        self.fleet = fleet_lib.write(
            self.fleet, jnp.asarray(ids), jnp.asarray(data),
            jnp.asarray(tmask),
        )
        landed = np.asarray(self.fleet.alloc_count) - before
        for t in np.flatnonzero(tmask):
            # write grants rows batch-prefix-first: exactly the first
            # ``landed[t]`` pages of the batch hit the disk
            for i in range(int(landed[t])):
                self.expected[t][int(ids[t, i])] = data[t, i].copy()
        return ("write", tmask.tolist(), landed.tolist())

    def ev_snapshot(self):
        mask = (self.rng.random(self.config.n_tenants) < 0.5) \
            & ~self._owner_mask()
        self.fleet = fleet_lib.snapshot(self.fleet, jnp.asarray(mask))
        return ("snapshot", mask.tolist())

    def ev_stream(self):
        mask = self.rng.random(self.config.n_tenants) < 0.5
        upto = int(self.rng.integers(0, self.config.max_chain - 1))
        self.fleet = fleet_lib.stream_tenants(self.fleet, mask, upto,
                                              registry=self.registry)
        return ("stream", mask.tolist(), upto)

    def ev_compact(self):
        self.fleet = fleet_lib.compact(self.fleet, registry=self.registry)
        return ("compact",)

    def ev_tick(self):
        rep = self.sched.tick()
        return ("tick", sorted(rep) if isinstance(rep, dict) else ())

    def ev_demote(self):
        # an owner pick demotes nothing (registry skip) and a fork pick
        # must leave the pinned base rows hot — both are the demote/fork
        # race the registry exists to win, so no masking here
        t = self._pick_tenant()
        self.fleet, rep = fleet_lib.demote_tenants(
            self.fleet, self.store, [t],
            max_rows=int(self.rng.integers(4, 17)),
            registry=self.registry,
        )
        return ("demote", t, rep["rows_demoted"])

    def ev_promote(self):
        t = self._pick_tenant()
        if int(self.fleet.cold_count[t]) == 0:
            return ("promote", t, "no_cold")
        try:
            self.fleet, rep = fleet_lib.promote_tenants(
                self.fleet, self.store, [t]
            )
        except RuntimeError:
            # device pool can't take the rows back right now — a legal
            # outcome under pressure, not an invariant violation
            return ("promote", t, "pool_exhausted")
        return ("promote", t, rep["rows_promoted"])

    def ev_free_attach(self):
        t = self._pick_tenant()
        if self.registry.is_golden_owner(t):
            return ("free_attach", t, "golden_owner")
        scalable = bool(self.rng.integers(2))
        # freeing a golden fork releases its pins inside free_tenant
        self.fleet = fleet_lib.free_tenant(self.fleet, t, store=self.store,
                                           registry=self.registry)
        self.fleet = fleet_lib.attach_tenant(self.fleet, t, scalable=scalable,
                                             registry=self.registry)
        self.expected[t] = {}
        return ("free_attach", t, scalable)

    def ev_migrate(self):
        """Move a tenant to the different-geometry destination fleet,
        bit-verified; a previous migrant in the landing slot is evicted
        (import resets the slot)."""
        t = self._pick_tenant()
        if self.registry.is_golden_owner(t):
            # a frozen base can't leave while forks may pin it
            return ("migrate", t, "golden_owner")
        d = int(self.rng.integers(self.config.dst_tenants))
        # migrating a fork is legal: export materializes the shared pages
        # into the blob and detach releases the pins
        self.fleet, self.dst_fleet, report = migrate.migrate_tenant(
            self.fleet, t, self.dst_fleet, d,
            src_store=self.store, dst_store=self.dst_store,
            src_registry=self.registry,
        )
        self.fleet = fleet_lib.attach_tenant(self.fleet, t, scalable=True,
                                             registry=self.registry)
        self.dst_expected[d] = self.expected[t]
        self.expected[t] = {}
        return ("migrate", t, d, report["rows_hot"], report["rows_cold"])

    def ev_mid_migration_write(self):
        """A write lands between export and detach: the stale-blob guard
        must refuse the detach and leave the source tenant intact."""
        c = self.config
        t = self._pick_tenant()
        if self.registry.is_golden_owner(t):
            return ("mid_migration_write", t, "golden_owner")
        blob = migrate.export_tenant(self.fleet, t, store=self.store)
        ids = np.broadcast_to(
            self.rng.choice(c.n_pages, c.write_batch,
                            replace=False).astype(np.int32),
            (c.n_tenants, c.write_batch),
        )
        data = self.rng.standard_normal(
            (c.n_tenants, c.write_batch, c.page_size)
        ).astype(np.float32)
        mask = np.zeros(c.n_tenants, bool)
        mask[t] = True
        before = int(self.fleet.alloc_count[t])
        self.fleet = fleet_lib.write(
            self.fleet, jnp.asarray(ids), jnp.asarray(data),
            jnp.asarray(mask),
        )
        landed = int(self.fleet.alloc_count[t]) - before
        for i in range(landed):
            self.expected[t][int(ids[t, i])] = data[t, i].copy()
        if migrate.tenant_fingerprint(self.fleet, t) == blob.fingerprint:
            # pool-wedged tenant: nothing landed, the blob is still good
            return ("mid_migration_write", t, "wedged_no_change")
        try:
            migrate.detach_tenant(self.fleet, t, blob, store=self.store)
        except migrate.MigrationError:
            self.guard_hits += 1
            return ("mid_migration_write", t, "guard_fired")
        raise AssertionError(
            f"detach of tenant {t} accepted a stale export"
        )

    # -- fleet-plane golden events --------------------------------------------

    def ev_golden_register(self):
        """Freeze a tenant's chain as a golden base. Keeps at least two
        tenants writable so the write/snapshot churn never starves."""
        owners = np.flatnonzero(self._owner_mask())
        if owners.size >= self.config.n_tenants - 2:
            return ("golden_register", "enough_owners")
        cands = [t for t in range(self.config.n_tenants)
                 if self.registry.gid_of(t) is None]
        t = cands[int(self.rng.integers(len(cands)))]
        if int(self.fleet.cold_count[t]) > 0:
            # golden layers must be device-resident; promote first
            try:
                self.fleet, _ = fleet_lib.promote_tenants(
                    self.fleet, self.store, [t])
            except RuntimeError:
                return ("golden_register", t, "pool_exhausted")
        gid, created = self.registry.register(self.fleet, t,
                                              store=self.store)
        return ("golden_register", t, gid, created)

    def ev_golden_fork(self):
        """Fork a registered base into a free slot: the fork's layers
        alias the owner's pinned rows, its oracle starts as the owner's
        frozen view, and later writes overlay it copy-on-write."""
        gids = sorted(self.registry._chains)
        if not gids:
            return ("golden_fork", "no_chains")
        gid = gids[int(self.rng.integers(len(gids)))]
        ch = self.registry._chains[gid]
        cands = [t for t in range(self.config.n_tenants)
                 if self.registry.gid_of(t) is None]
        if not cands:
            return ("golden_fork", gid, "no_free_slot")
        dst = cands[int(self.rng.integers(len(cands)))]
        try:
            self.fleet = self.registry.fork(self.fleet, gid, dst,
                                            store=self.store)
        except ValueError:
            # chain too deep for a fresh active volume on top
            return ("golden_fork", gid, dst, "no_chain_room")
        self.expected[dst] = {
            p: row.copy() for p, row in self.expected[ch.tenant].items()
        }
        return ("golden_fork", gid, dst, ch.length)

    def ev_golden_release(self):
        """Free a live fork (releasing its pins), or unregister a base
        with no forks left — the full golden lifecycle unwinds."""
        forks = sorted(self.registry._forks)
        if forks:
            t = forks[int(self.rng.integers(len(forks)))]
            self.fleet = fleet_lib.free_tenant(
                self.fleet, t, store=self.store, registry=self.registry)
            self.fleet = fleet_lib.attach_tenant(
                self.fleet, t, scalable=True, registry=self.registry)
            self.expected[t] = {}
            return ("golden_release", "fork", t)
        idle = sorted(gid for gid, ch in self.registry._chains.items()
                      if not ch.fork_count)
        if not idle:
            return ("golden_release", "all_pinned")
        gid = idle[int(self.rng.integers(len(idle)))]
        self.registry.unregister(gid)
        return ("golden_release", "unregister", gid)

    # -- serving-plane events -------------------------------------------------

    def _kv_tokens(self, n: int):
        c = self.config
        shape = (c.kv_layers, n, c.kv_heads, c.kv_head_dim)
        return (self.rng.standard_normal(shape).astype(np.float32),
                self.rng.standard_normal(shape).astype(np.float32))

    def _kv_live(self, *, unparked: bool = False,
                 writable: bool = False) -> list[int]:
        sids = sorted(s for s, q in self.kv._seqs.items() if not q.freed)
        if unparked:
            sids = [s for s in sids if s not in self.kv_parked]
        if writable:
            # registered golden prefixes are frozen: no append, park,
            # free or migrate-away — they can only be forked or released
            sids = [s for s in sids if s not in self.kv_golden]
        return sids

    def _kv_room(self, blocks: int) -> bool:
        return len(self.kv._free) >= blocks + 2

    def ev_kv_new(self):
        sid = self.kv.new_seq()
        n = int(self.rng.integers(1, 5))
        bs = self.config.kv_block_size
        if not self._kv_room(-(-n // bs)):
            self.kv_expected[sid] = self._kv_tokens(0)
            return ("kv_new", sid, 0)
        k, v = self._kv_tokens(n)
        self.kv.append_prefill(sid, jnp.asarray(k), jnp.asarray(v))
        self.kv_expected[sid] = (k, v)
        return ("kv_new", sid, n)

    def ev_kv_append(self):
        sids = self._kv_live(unparked=True, writable=True)
        if not sids:
            return ("kv_append", "no_live")
        sid = sids[int(self.rng.integers(len(sids)))]
        n = int(self.rng.integers(1, 5))
        c, bs = self.config, self.config.kv_block_size
        seq = self.kv._seqs[sid]
        if (seq.length + n - 1) // bs >= c.kv_max_blocks:
            return ("kv_append", sid, "at_max")
        if not self._kv_room(-(-n // bs) + 2):
            return ("kv_append", sid, "pool_low")
        k, v = self._kv_tokens(n)
        self.kv.append_prefill(sid, jnp.asarray(k), jnp.asarray(v))
        ek, ev = self.kv_expected[sid]
        self.kv_expected[sid] = (np.concatenate([ek, k], axis=1),
                                 np.concatenate([ev, v], axis=1))
        return ("kv_append", sid, n)

    def ev_kv_fork_storm(self):
        """Fork a live sequence 1–3 times; forking a *parked* parent
        exercises the promote-on-fork race (a spilled table can't be
        shared by block id, so the cache un-spills it first)."""
        sids = self._kv_live()
        if not sids:
            return ("kv_fork_storm", "no_live")
        sid = sids[int(self.rng.integers(len(sids)))]
        n_children = int(self.rng.integers(1, 4))
        children = []
        for _ in range(n_children):
            # room for the promote-on-fork un-spill plus slack
            if not self._kv_room(len(self.kv._seqs[sid].cold) + 2):
                break
            child = self.kv.fork(sid)
            ek, ev = self.kv_expected[sid]
            self.kv_expected[child] = (ek.copy(), ev.copy())
            children.append(child)
        return ("kv_fork_storm", sid, children)

    def ev_kv_free(self):
        sids = self._kv_live(writable=True)
        if len(sids) <= 1:
            return ("kv_free", "too_few")
        sid = sids[int(self.rng.integers(len(sids)))]
        self.kv.free_seq(sid)
        self.kv_parked.discard(sid)
        del self.kv_expected[sid]
        return ("kv_free", sid)

    def ev_kv_park(self):
        sids = self._kv_live(unparked=True, writable=True)
        if not sids:
            return ("kv_park", "no_live")
        sid = sids[int(self.rng.integers(len(sids)))]
        spilled = self.kv.demote_seq(sid)
        self.kv_parked.add(sid)
        return ("kv_park", sid, spilled)

    def ev_kv_resume(self):
        if not self.kv_parked:
            return ("kv_resume", "none_parked")
        sids = sorted(self.kv_parked)
        sid = sids[int(self.rng.integers(len(sids)))]
        if not self._kv_room(len(self.kv._seqs[sid].cold)):
            return ("kv_resume", sid, "pool_low")
        promoted = self.kv.promote_seq(sid)
        self.kv_parked.discard(sid)
        return ("kv_resume", sid, promoted)

    def ev_kv_migrate(self):
        """Move a sequence (parked ones included — their spill is read in
        place) to the second cache, verify bit-identity, then free it on
        the source so tombstoned ancestors cascade."""
        sids = self._kv_live(writable=True)
        if not sids:
            return ("kv_migrate", "no_live")
        sid = sids[int(self.rng.integers(len(sids)))]
        seq = self.kv._seqs[sid]
        need = -(-seq.length // self.config.kv_dst_block_size)
        if len(self.kv_dst._free) < need + 2:
            return ("kv_migrate", sid, "dst_pool_low")
        blob = self.kv.export_seq(sid)
        new_sid = self.kv_dst.import_seq(blob)
        gk, gv = self.kv_dst.gather(new_sid)
        assert (np.asarray(gk) == blob["k"]).all() \
            and (np.asarray(gv) == blob["v"]).all(), (
            f"migrated sid {sid} not bit-identical on the destination"
        )
        self.kv.free_seq(sid)
        self.kv_parked.discard(sid)
        self.kv_dst_expected[new_sid] = self.kv_expected.pop(sid)
        return ("kv_migrate", sid, new_sid, seq.length)

    def ev_kv_mid_migration(self):
        """A decode-style append lands after export: the fingerprint must
        change, so the migration would abort rather than drop the source."""
        sids = self._kv_live(unparked=True, writable=True)
        if not sids:
            return ("kv_mid_migration", "no_live")
        sid = sids[int(self.rng.integers(len(sids)))]
        seq = self.kv._seqs[sid]
        bs = self.config.kv_block_size
        if (seq.length // bs >= self.config.kv_max_blocks
                or not self._kv_room(3)):
            return ("kv_mid_migration", sid, "at_max")
        blob = self.kv.export_seq(sid)
        k, v = self._kv_tokens(1)
        self.kv.append_prefill(sid, jnp.asarray(k), jnp.asarray(v))
        ek, ev = self.kv_expected[sid]
        self.kv_expected[sid] = (np.concatenate([ek, k], axis=1),
                                 np.concatenate([ev, v], axis=1))
        assert self.kv.seq_fingerprint(sid) != blob["fingerprint"], (
            f"sid {sid}: append landed after export but the fingerprint "
            "did not change — the mid-flight guard is blind"
        )
        self.guard_hits += 1
        return ("kv_mid_migration", sid, "guard_fired")

    # -- serving-plane golden events ------------------------------------------

    def ev_kv_golden_register(self):
        """Freeze a live sequence as a golden shared-prefix base."""
        if len(self.kv_golden) >= 3:
            return ("kv_golden_register", "enough_goldens")
        sids = [s for s in self._kv_live(unparked=True, writable=True)
                if self.kv.seq_length(s) > 0]
        if not sids:
            return ("kv_golden_register", "no_live")
        sid = sids[int(self.rng.integers(len(sids)))]
        h = self.kv.register_golden(sid)
        self.kv_golden.add(sid)
        return ("kv_golden_register", sid, h[:8])

    def ev_kv_golden_admit(self):
        """Prefix-hit admission: fork a golden base and append a short
        suffix — the engine's ``add_request`` fast path, KV-plane form.
        A zero-length suffix is the exact-match admission."""
        goldens = sorted(self.kv_golden)
        if not goldens:
            return ("kv_golden_admit", "no_goldens")
        sid = goldens[int(self.rng.integers(len(goldens)))]
        if not self._kv_room(4):
            return ("kv_golden_admit", sid, "pool_low")
        child = self.kv.fork(sid)
        ek, ev = self.kv_expected[sid]
        self.kv_expected[child] = (ek.copy(), ev.copy())
        n = int(self.rng.integers(0, 4))
        c, bs = self.config, self.config.kv_block_size
        if n and (self.kv.seq_length(child) + n - 1) // bs < c.kv_max_blocks:
            k, v = self._kv_tokens(n)
            self.kv.append_prefill(child, jnp.asarray(k), jnp.asarray(v))
            ek, ev = self.kv_expected[child]
            self.kv_expected[child] = (np.concatenate([ek, k], axis=1),
                                       np.concatenate([ev, v], axis=1))
        else:
            n = 0
        return ("kv_golden_admit", sid, child, n)

    def ev_kv_golden_release(self):
        """Unfreeze and free a golden base; children survive through
        their parent links (vanilla tombstone cascade)."""
        goldens = sorted(self.kv_golden)
        if not goldens:
            return ("kv_golden_release", "no_goldens")
        sid = goldens[int(self.rng.integers(len(goldens)))]
        self.kv.release_golden(sid)
        self.kv.free_seq(sid)
        self.kv_golden.discard(sid)
        del self.kv_expected[sid]
        return ("kv_golden_release", sid)

    # -- checking -------------------------------------------------------------

    def check(self, *, data: bool = False):
        """Run the shared invariant suite over every plane; with
        ``data=True`` also compare the independent oracles bit-for-bit."""
        check_fleet_invariants(self.fleet, store=self.store,
                               registry=self.registry)
        check_fleet_invariants(self.dst_fleet, store=self.dst_store)
        check_kv_invariants(self.kv)
        check_kv_invariants(self.kv_dst)
        self.invariant_checks += 1
        if data:
            self._check_fleet_data(self.fleet, self.store, self.expected,
                                   "src")
            self._check_fleet_data(self.dst_fleet, self.dst_store,
                                   self.dst_expected, "dst")
            self._check_kv_data(self.kv, self.kv_expected, "src")
            self._check_kv_data(self.kv_dst, self.kv_dst_expected, "dst")

    def _check_fleet_data(self, fl, st, expected, label):
        spec = fl.spec
        grid = np.broadcast_to(np.arange(spec.n_pages, dtype=np.int32),
                               (spec.n_tenants, spec.n_pages))
        got, _ = fleet_lib.read_tiered(fl, st, grid)
        overflowed = np.asarray(fl.overflow)
        for t, pages in expected.items():
            if overflowed[t]:
                # a wedged tenant may have dropped later writes the
                # oracle can't see the boundary of; structural invariants
                # still apply, the data oracle re-syncs
                for p in range(spec.n_pages):
                    expected[t][p] = np.array(got[t, p])
                continue
            want = np.zeros((spec.n_pages, spec.page_size), np.float32)
            for p, row in pages.items():
                want[p] = row
            assert (got[t] == want).all(), (
                f"{label} fleet tenant {t}: guest pages "
                f"{np.flatnonzero((got[t] != want).any(axis=1)).tolist()} "
                "differ from the event-by-event oracle"
            )

    def _check_kv_data(self, cache, expected, label):
        for sid, (ek, ev) in expected.items():
            gk, gv = cache.gather(sid)
            assert (np.asarray(gk) == ek).all() \
                and (np.asarray(gv) == ev).all(), (
                f"{label} cache sid {sid}: gathered KV differs from the "
                "event-by-event oracle"
            )

    # -- driving --------------------------------------------------------------

    def step(self) -> tuple:
        i = int(self.rng.choice(len(self._events), p=self._weights))
        record = self._events[i][0]()
        self._step += 1
        self.trace.append((self._step,) + record)
        self.check(data=self._step % self.config.check_data_every == 0)
        return record

    def run(self) -> list[tuple]:
        for _ in range(self.config.events):
            self.step()
        self.check(data=True)
        return self.trace

    def stats(self) -> dict:
        return dict(
            events=self._step,
            invariant_checks=self.invariant_checks,
            guard_hits=self.guard_hits,
            live_seqs=len(self._kv_live()),
            fleet_rows=int(np.asarray(self.fleet.alloc_count).sum()),
            host_rows=self.store.host_rows_in_use(),
            golden_chains=self.registry.stats()["golden_chains"],
            golden_forks=self.registry.stats()["golden_forks"],
            kv_goldens=len(self.kv_golden),
        )
