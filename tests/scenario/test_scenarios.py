"""Scenario-harness CI gates: fixed-seed smoke storms, replay
determinism, and the harness self-test (a deliberately broken fleet the
invariant suite must catch — a checker that can't fail proves nothing).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet as fleet_lib
from repro.core import format as fmt
from repro.core.invariants import (
    check_fleet_invariants,
    check_kv_invariants,
    check_store_invariants,
)
from repro.core.store import TieredStore
from repro.kvcache.paged import PagedKVCache, PagedKVConfig

from tests.scenario.harness import ScenarioConfig, ScenarioHarness

SMOKE_SEEDS = [0, 1, 2]


@pytest.fixture(scope="module")
def storms():
    """One >= 200-event storm per smoke seed; every event already ran the
    invariant suite (run() raises on the first violation)."""
    out = {}
    for seed in SMOKE_SEEDS:
        h = ScenarioHarness(ScenarioConfig(seed=seed, events=200))
        h.run()
        out[seed] = h
    return out


@pytest.mark.parametrize("seed", SMOKE_SEEDS)
def test_smoke_storm(seed, storms):
    h = storms[seed]
    assert len(h.trace) == 200
    assert h.stats()["invariant_checks"] >= 200


def test_guard_events_fire(storms):
    """The storms must actually exercise the mid-migration guards, not
    just schedule them."""
    assert sum(h.stats()["guard_hits"] for h in storms.values()) > 0


def test_storms_cover_both_planes(storms):
    """Every storm must hit fleet-plane and serving-plane events — a
    degenerate weight table would quietly hollow out the suite."""
    for h in storms.values():
        kinds = {e[1] for e in h.trace}
        assert any(k.startswith("kv_") for k in kinds)
        assert any(not k.startswith("kv_") for k in kinds)
        assert "migrate" in kinds or "kv_migrate" in kinds


def test_storms_exercise_golden_plane(storms):
    """The storms must register, fork AND release golden bases on both
    planes — the registry guards only matter under concurrent churn."""
    kinds = {e[1] for h in storms.values() for e in h.trace}
    assert {"golden_register", "golden_fork", "golden_release"} <= kinds
    assert {"kv_golden_register", "kv_golden_admit",
            "kv_golden_release"} <= kinds
    # at least one *successful* fork per plane (not just no-op probes):
    # a fleet fork record ends with the chain length, a KV admission
    # record with the suffix length — both ints only on success
    assert any(e[1] == "golden_fork" and isinstance(e[-1], int)
               for h in storms.values() for e in h.trace)
    assert any(e[1] == "kv_golden_admit" and isinstance(e[-1], int)
               for h in storms.values() for e in h.trace)


def test_replay_determinism():
    """Same seed, same config ⇒ byte-identical event trace."""
    cfg = ScenarioConfig(seed=7, events=120)
    assert ScenarioHarness(cfg).run() == ScenarioHarness(cfg).run()


def test_seeds_diverge():
    """Different seeds must explore different event sequences — a trace
    that ignores its seed would make the seed matrix worthless."""
    a = ScenarioHarness(ScenarioConfig(seed=1, events=60)).run()
    b = ScenarioHarness(ScenarioConfig(seed=2, events=60)).run()
    assert [e[1:] for e in a] != [e[1:] for e in b]


@pytest.mark.slow
def test_long_randomized_storm():
    """The deep soak: more seeds, an order of magnitude more events."""
    for seed in range(3, 6):
        h = ScenarioHarness(ScenarioConfig(seed=seed, events=1500))
        h.run()
        assert h.stats()["invariant_checks"] >= 1500


# -- harness self-test: the suite must catch a deliberately broken fleet ------


@pytest.fixture(scope="module")
def grown(storms):
    """A storm-grown harness for read-only corruption probes (corruptions
    below go through dataclasses.replace, never the shared state)."""
    return storms[SMOKE_SEEDS[0]]


def test_invariants_catch_stolen_lease(grown):
    """Clearing a held quantum's owner breaks lease/free-list agreement."""
    fl = grown.fleet
    owner = np.asarray(fl.lease_owner).copy()
    held = np.flatnonzero(owner >= 0)
    assert held.size, "storm left no leases to corrupt"
    owner[held[0]] = -1
    broken = dataclasses.replace(fl, lease_owner=jnp.asarray(owner))
    with pytest.raises(AssertionError):
        check_fleet_invariants(broken, store=grown.store)


def test_invariants_catch_foreign_row(grown):
    """Re-pointing one tenant's L2 entry at another tenant's leased row
    is exactly the cross-tenant aliasing the allocator exists to
    prevent."""
    fl = grown.fleet
    owner = np.asarray(fl.lease_owner)
    held = np.flatnonzero(owner >= 0)
    assert held.size
    victim_q = int(held[0])
    thief = (int(owner[victim_q]) + 1) % fl.spec.n_tenants
    foreign = victim_q * fl.spec.lease_quantum
    entry = fmt.pack_entry(foreign, 0, allocated=True, bfi_valid=False)
    l2 = fl.l2.at[thief, 0, 0].set(entry)
    broken = dataclasses.replace(fl, l2=l2)
    with pytest.raises(AssertionError):
        check_fleet_invariants(broken, store=grown.store)


def test_invariants_catch_cold_count_drift(grown):
    fl = grown.fleet
    cc = np.asarray(fl.cold_count).copy()
    cc[0] += 1
    broken = dataclasses.replace(fl, cold_count=jnp.asarray(cc))
    with pytest.raises(AssertionError):
        check_fleet_invariants(broken, store=grown.store)


def test_invariants_catch_double_free_host_row():
    spec = fleet_lib.FleetSpec(n_tenants=2, n_pages=32, page_size=4,
                               max_chain=4, pool_capacity=64,
                               lease_quantum=8, l2_per_table=32)
    store = TieredStore.for_fleet(spec)
    rows = store.alloc(4)
    store.free(rows[:2])
    store._free.append(int(rows[0]))    # the deliberate corruption
    with pytest.raises(AssertionError):
        check_store_invariants(store)


def _small_cache():
    cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4, block_size=4,
                        n_blocks=16, max_blocks_per_seq=4,
                        dtype=jnp.float32)
    cache = PagedKVCache(cfg, scalable=False)
    sid = cache.new_seq()
    k = jnp.zeros((1, 6, 1, 4), jnp.float32)
    cache.append_prefill(sid, k, k)
    check_kv_invariants(cache)
    return cache, sid


def test_invariants_catch_refcount_drift():
    cache, _ = _small_cache()
    refd = np.flatnonzero(np.asarray(cache._ref) > 0)
    assert refd.size
    cache._ref[int(refd[0])] += 1       # the deliberate corruption
    with pytest.raises(AssertionError):
        check_kv_invariants(cache)


def test_invariants_catch_orphaned_spill():
    cache, sid = _small_cache()
    cache._cold_kv[sid] = {0: (np.zeros(1), np.zeros(1))}   # no seq.cold
    with pytest.raises(AssertionError):
        check_kv_invariants(cache)
