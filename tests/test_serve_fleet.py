"""Fleet-backed serving plane: the stacked fleet resolve must stay
bit-identical to the retained numpy oracle (``_resolve_oracle``) across
formats, fork depths, resolver methods, and full engine lifecycles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet as fleet_lib
from repro.kvcache.paged import PagedKVCache, PagedKVConfig

KV = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4, block_size=4,
                   n_blocks=512, max_blocks_per_seq=16, dtype=jnp.float32)


def tok(val: float):
    arr = jnp.full((KV.n_layers, 1, KV.n_kv_heads, KV.head_dim), val,
                   jnp.float32)
    return arr[:, 0]


def prompt(n: int, base: float = 1.0):
    k = jnp.arange(n, dtype=jnp.float32)[None, :, None, None] + base
    return jnp.broadcast_to(
        k, (KV.n_layers, n, KV.n_kv_heads, KV.head_dim)
    )


def assert_parity(cache: PagedKVCache, sids) -> None:
    """Fleet-resolved tables/owners ≡ numpy oracle, plus the refcount
    invariant behind ``blocks_in_use``."""
    tables, owners, _, _ = cache._resolve_all()
    n_tbl, _ = cache.batched_tables(sids)
    n_tbl = np.asarray(n_tbl)
    for i, sid in enumerate(sids):
        seq = cache._seqs[sid]
        o_table, o_owner, _ = cache._resolve_oracle(sid)
        np.testing.assert_array_equal(
            tables[seq.tenant], o_table,
            err_msg=f"sid={sid} fleet table != oracle"
        )
        np.testing.assert_array_equal(n_tbl[i], o_table)
        # owner parity: the walk reports the owning chain layer — map it
        # back to a sid through the fork path; direct reports the bfi sid
        f_owner = owners[seq.tenant]
        if not cache.scalable:
            f_owner = np.asarray([
                seq.path[layer] if layer >= 0 else -1 for layer in f_owner
            ])
        np.testing.assert_array_equal(
            np.where(o_table >= 0, f_owner, -1),
            np.where(o_table >= 0, o_owner, -1),
            err_msg=f"sid={sid} fleet owner != oracle owner",
        )
    # blocks_in_use comes from the refcounts; they must agree with the
    # union of every (live or tombstoned) sequence's ref set
    held = set()
    for seq in cache._seqs.values():
        held |= seq.refs
    assert cache.blocks_in_use() == len(held)


@pytest.mark.parametrize("scalable", [True, False])
@pytest.mark.parametrize("depth", [1, 8, 33])
def test_fork_chain_parity(scalable, depth):
    """Chain of ``depth`` forks (every node appends, alternate nodes are
    retired) — the stacked fleet resolve tracks the live walk exactly,
    including through tenant-axis and chain-axis growth."""
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    cache.append_prefill(sid, prompt(6), prompt(6))
    live = [sid]
    val = 10.0
    for d in range(depth):
        child = cache.fork(sid)
        cache.append(child, tok(val), tok(val))
        val += 1.0
        if d % 2 == 0:                 # tombstone every other parent
            cache.free_seq(sid)
            live.remove(sid)
        live.append(child)
        sid = child
    assert_parity(cache, live)
    # content sanity through the deepest leaf
    k, _ = cache.gather(sid)
    assert int(k.shape[1]) == cache.seq_length(sid)


@pytest.mark.parametrize("scalable", [True, False])
def test_parent_writes_propagate_to_forked_tables(scalable):
    """The live-walk corner: a parent COWs/allocates *after* forking, and
    the child's stacked table must show it exactly as the oracle walk
    does (vanilla forks copy ancestor layers — writes propagate)."""
    cache = PagedKVCache(KV, scalable=scalable)
    g = cache.new_seq()
    cache.append_prefill(g, prompt(6), prompt(6))      # blocks 0, 1(partial)
    a = cache.fork(g)
    for i in range(2):                                 # a COWs g's block 1
        cache.append(a, tok(20.0 + i), tok(20.0 + i))
    b = cache.fork(a)                                  # forked at length 8
    for i in range(5):                                 # a runs ahead: blocks 2, 3
        cache.append(a, tok(30.0 + i), tok(30.0 + i))
    assert_parity(cache, [g, a, b])
    # b now diverges: COW at its boundary block must not disturb a
    cache.append(b, tok(40.0), tok(40.0))
    assert_parity(cache, [g, a, b])
    bk, _ = cache.gather(b)
    ak, _ = cache.gather(a)
    np.testing.assert_allclose(np.asarray(bk[0, :8, 0, 0]),
                               np.asarray(ak[0, :8, 0, 0]))
    assert float(bk[0, 8, 0, 0]) == 40.0
    assert float(ak[0, 8, 0, 0]) == 30.0


@pytest.mark.parametrize("scalable", [True, False])
def test_lane_aligned_pool_takes_kernel_path(scalable):
    """With a 128-page (lane-aligned) table the ``auto`` resolver runs the
    stacked Pallas kernels (interpret mode on CPU) — results must stay
    bit-identical to the oracle."""
    cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4, block_size=4,
                        n_blocks=512, max_blocks_per_seq=128,
                        dtype=jnp.float32)
    cache = PagedKVCache(cfg, scalable=scalable)
    assert fleet_lib._uses_kernels(cache.fleet.spec, "auto")
    sid = cache.new_seq()
    k = jnp.ones((1, 6, 1, 4), jnp.float32)
    cache.append_prefill(sid, k, k)
    child = cache.fork(sid)
    cache.append(child, tok(2.0), tok(2.0))
    for s in (sid, child):
        o_table, _, _ = cache._resolve_oracle(s)
        np.testing.assert_array_equal(np.asarray(cache.block_table(s)),
                                      o_table)


@pytest.mark.parametrize("scalable,methods", [
    (False, ["auto", "vanilla", "gather", "pallas_vanilla"]),
    (True, ["auto", "direct", "pallas_direct"]),
])
def test_resolver_methods_bit_identical(scalable, methods):
    cache = PagedKVCache(KV, scalable=scalable)
    sid = cache.new_seq()
    cache.append_prefill(sid, prompt(9), prompt(9))
    child = cache.fork(sid)
    cache.append(child, tok(5.0), tok(5.0))
    rows = {}
    for m in methods:
        cache.resolver = m
        tables, _, _, _ = cache._resolve_all()
        rows[m] = tables
    ref = rows[methods[0]]
    for m in methods[1:]:
        np.testing.assert_array_equal(rows[m], ref, err_msg=m)
    cache.resolver = "auto"
    assert_parity(cache, [sid, child])


def test_tombstoned_reads_raise():
    """Regression (satellite): ``gather``/``block_table``/``batched_tables``
    on a freed-but-tombstoned sequence must raise, not silently return the
    dead sequence's data."""
    cache = PagedKVCache(KV, scalable=False)
    sid = cache.new_seq()
    cache.append_prefill(sid, prompt(6), prompt(6))
    child = cache.fork(sid)
    cache.free_seq(sid)          # tombstoned: child still pins it
    assert sid in cache._seqs
    with pytest.raises(KeyError):
        cache.gather(sid)
    with pytest.raises(KeyError):
        cache.block_table(sid)
    with pytest.raises(KeyError):
        cache.batched_tables([sid])
    # the live child still resolves through the tombstone
    cache.gather(child)


def test_star_fork_reap_keeps_child_counts():
    """One parent, many children (the O(N²) rescan regression): frees in
    arbitrary order must reap exactly when the last descendant goes."""
    cache = PagedKVCache(KV, scalable=False)
    root = cache.new_seq()
    cache.append_prefill(root, prompt(5), prompt(5))
    kids = [cache.fork(root) for _ in range(6)]
    assert cache._seqs[root].children == 6
    cache.free_seq(root)                      # tombstoned, 6 pins
    for kid in kids[:-1]:
        cache.free_seq(kid)
        assert root in cache._seqs            # still pinned
    cache.free_seq(kids[-1])
    assert cache._seqs == {}
    assert cache.blocks_in_use() == 0


def test_tenant_rows_recycle_without_aliasing():
    """Freed sequences release their fleet tenant rows; new sequences
    reuse the slots with clean tables."""
    cache = PagedKVCache(KV, scalable=False)
    sids = [cache.new_seq() for _ in range(5)]
    for s in sids:
        cache.append_prefill(s, prompt(4, base=float(s)), prompt(4))
    rows_before = {s: cache._seqs[s].tenant for s in sids}
    for s in sids[:3]:
        cache.free_seq(s)
    fresh = [cache.new_seq() for _ in range(3)]
    assert {cache._seqs[s].tenant for s in fresh} == {
        rows_before[s] for s in sids[:3]
    }
    for s in fresh:
        # a recycled row starts empty: no inherited blocks
        np.testing.assert_array_equal(np.asarray(cache.block_table(s)),
                                      np.full(KV.max_blocks_per_seq, -1))
    assert_parity(cache, sids[3:] + fresh)


def test_engine_deep_chain_lifecycle_matches_oracle():
    """Satellite: engine lifecycle under the vanilla cache — fork chains
    past depth 32 with interleaved ``finish_request``/``step``, asserting
    the fleet-backed plane ≡ the host-numpy oracle on tables, lengths and
    ``blocks_in_use`` throughout."""
    import jax

    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.serve.engine import Engine

    cfg = smoke_config("qwen2-7b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, scalable=False, n_blocks=256, block_size=4,
                 max_blocks_per_seq=64)
    prompt_toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (9,), 0, cfg.vocab_size))
    sid = eng.add_request(prompt_toks)
    keeper = eng.fork_request(sid)    # long-lived sibling rides along
    for depth in range(34):
        child = eng.fork_request(sid)
        eng.finish_request(sid)       # tombstone the parent
        sid = child
        if depth % 8 == 0:
            out = eng.step()          # decode the whole active set
            assert set(out) == set(eng.active)
            assert_parity(eng.kv, sorted(eng.active))
    assert len(eng.kv._seqs[sid].path) >= 34
    assert_parity(eng.kv, sorted(eng.active))
    for s in sorted(eng.active):
        # active[s] holds generated tokens; the newest one is not yet
        # committed to the cache (it lands at the next step's scatter)
        assert eng.kv.seq_length(s) == len(prompt_toks) + len(eng.active[s]) - 1
    eng.finish_request(keeper)
    eng.finish_request(sid)
    assert eng.kv.blocks_in_use() == 0
    assert eng.kv._seqs == {}


def test_same_step_cow_onto_recycled_block_keeps_data():
    """Regression: within one ``prepare_step`` batch, an earlier COW can
    free a block that a later COW then recycles as its *destination*.
    The batched data movement must still read every source's pre-step
    content in sequence order — the corrupting order would copy the
    recycled block after it was overwritten."""
    cache = PagedKVCache(KV, scalable=True)
    r = cache.new_seq()
    cache.append_prefill(r, prompt(1, base=100.0), prompt(1, base=100.0))
    c1 = cache.fork(r)
    cache.free_seq(r)          # ref on r's block drops to c1 alone
    s = cache.new_seq()
    cache.append_prefill(s, prompt(1, base=200.0), prompt(1, base=200.0))
    c2 = cache.fork(s)
    cache.free_seq(s)
    # c1's COW frees r's old block; c2's COW pops it back as destination
    cache.prepare_step([c1, c2])
    k1, _ = cache.gather(c1)
    k2, _ = cache.gather(c2)
    assert float(k1[0, 0, 0, 0]) == 100.0
    assert float(k2[0, 0, 0, 0]) == 200.0
    assert_parity(cache, [c1, c2])


def test_same_step_chained_ancestor_descendant_cow():
    """Regression companion: a descendant COW-ing the block its ancestor
    COW-created *in the same step* must read the post-copy content
    (vanilla propagation patches the descendant's resolve mid-batch)."""
    cache = PagedKVCache(KV, scalable=False)
    g = cache.new_seq()
    cache.append_prefill(g, prompt(1, base=7.0), prompt(1, base=7.0))
    a = cache.fork(g)
    b = cache.fork(a)
    cache.prepare_step([g, a, b])    # a: COW g's block; b: COW a's new block
    ka, _ = cache.gather(a)
    kb, _ = cache.gather(b)
    assert float(ka[0, 0, 0, 0]) == 7.0
    assert float(kb[0, 0, 0, 0]) == 7.0
    assert_parity(cache, [g, a, b])


def test_vanilla_root_lookup_count_matches_oracle():
    """Regression: an unforked vanilla root is resolved directly by the
    oracle (charges only allocated blocks); the fleet path's accounting
    must match, not charge every page."""
    cache = PagedKVCache(KV, scalable=False)
    sid = cache.new_seq()
    cache.append_prefill(sid, prompt(8), prompt(8))    # 2 blocks of 4
    cache.lookup_count = 0
    cache.block_table(sid)
    _, _, oracle_lookups = cache._resolve_oracle(sid)
    assert cache.lookup_count == oracle_lookups == 2


def test_scalable_sids_past_bfi_width_keep_serving():
    """Regression: sequence ids are lifetime-monotonic; past the 16-bit
    bfi field they wrap in the (diagnostic) owner metadata but tables,
    COW and content must stay exact — a long-running engine must not
    die at 65k requests."""
    from repro.core import format as fmt

    cache = PagedKVCache(KV, scalable=True)
    cache._next_sid = fmt.BFI_MASK + 3
    sid = cache.new_seq()
    cache.append_prefill(sid, prompt(6), prompt(6))
    child = cache.fork(sid)
    cache.append(child, tok(9.0), tok(9.0))
    for s in (sid, child):
        o_table, _, _ = cache._resolve_oracle(s)
        np.testing.assert_array_equal(np.asarray(cache.block_table(s)),
                                      o_table)
    ck, _ = cache.gather(child)
    assert float(ck[0, 6, 0, 0]) == 9.0
    pk, _ = cache.gather(sid)
    assert pk.shape[1] == 6


def _mk_engines(cfg, params, scalable):
    """A tables-path and a fused-path engine built identically."""
    from repro.serve.engine import Engine

    mk = lambda path: Engine(cfg, params, scalable=scalable, n_blocks=256,
                             block_size=4, max_blocks_per_seq=128,
                             resolver="gather", decode_path=path)
    return mk("tables"), mk("fused")


@pytest.mark.parametrize("scalable", [True, False])
def test_fused_decode_path_matches_tables_path(scalable):
    """Tentpole parity: a full engine decode loop — fork propagation
    mid-loop, park/demote → resume with the cold promote-before-step —
    must emit identical tokens, identical KV bytes and identical
    allocation on the fused path and the tables path. (lookup_count is
    NOT compared: the two paths have different documented cost models.)
    """
    import jax

    from repro.configs import smoke_config
    from repro.models import get_model

    cfg = smoke_config("qwen2-7b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    eng_t, eng_f = _mk_engines(cfg, params, scalable)
    assert eng_t.decode_path == "tables" and eng_f.decode_path == "fused"
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 3)]
    sids_t = [eng_t.add_request(p) for p in prompts]
    sids_f = [eng_f.add_request(p) for p in prompts]
    for _ in range(6):
        assert eng_t.step() == eng_f.step()
    eng_t.fork_request(sids_t[1])          # fork propagation mid-loop
    eng_f.fork_request(sids_f[1])
    for _ in range(4):
        assert eng_t.step() == eng_f.step()
    spilled_t = eng_t.park_request(sids_t[0])   # host-tier spill
    spilled_f = eng_f.park_request(sids_f[0])
    assert spilled_t == spilled_f
    for _ in range(2):
        assert eng_t.step() == eng_f.step()
    eng_t.resume_request(sids_t[0])        # lazy: next step promotes
    eng_f.resume_request(sids_f[0])
    for _ in range(3):
        assert eng_t.step() == eng_f.step()
    for st, sf in zip(sids_t, sids_f):
        kt, vt = eng_t.kv.gather(st)
        kf, vf = eng_f.kv.gather(sf)
        np.testing.assert_array_equal(np.asarray(kt), np.asarray(kf))
        np.testing.assert_array_equal(np.asarray(vt), np.asarray(vf))
    assert eng_t.kv.blocks_in_use() == eng_f.kv.blocks_in_use()
    assert eng_t.kv.host_blocks_in_use() == eng_f.kv.host_blocks_in_use()


def test_fused_decode_path_auto_selection():
    """``decode_path="auto"`` picks fused iff the page axis is
    lane-aligned (``fused_layout_ok``); an explicit fused request on a
    non-aligned pool is a configuration error."""
    import jax

    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.serve.engine import Engine

    cfg = smoke_config("qwen2-7b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    mk = lambda mbs, path: Engine(cfg, params, scalable=False, n_blocks=64,
                                  block_size=4, max_blocks_per_seq=mbs,
                                  decode_path=path)
    assert fleet_lib.fused_layout_ok(128)
    assert not fleet_lib.fused_layout_ok(64)
    assert mk(128, "auto").decode_path == "fused"
    assert mk(64, "auto").decode_path == "tables"
    assert mk(64, "tables").decode_path == "tables"
    with pytest.raises(ValueError, match="lane-aligned"):
        mk(64, "fused")
    with pytest.raises(ValueError, match="decode_path"):
        mk(128, "sideways")


@pytest.mark.parametrize("scalable", [True, False])
def test_prepare_step_fused_plan_matches_tables(scalable):
    """On a settled cache the tables derived from a ``FusedStepPlan``
    (walk oracle over the plan's index) must be bit-identical to
    ``prepare_step``'s materialized tables, and the plan's write blocks
    must be the slots those tables hold at each write column."""
    from repro.kernels.paged_attention import ref as pa_ref

    cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4, block_size=4,
                        n_blocks=512, max_blocks_per_seq=128,
                        dtype=jnp.float32)
    cache = PagedKVCache(cfg, scalable=scalable)
    sid = cache.new_seq()
    cache.append_prefill(sid, prompt(6), prompt(6))
    a = cache.fork(sid)
    cache.append(a, tok(2.0), tok(2.0))
    b = cache.fork(a)
    cache.append(b, tok(3.0), tok(3.0))
    sids = sorted({sid, a, b})
    tables, lengths = cache.prepare_step(sids)         # settles the slots
    plan = cache.prepare_step_fused(sids)
    derived = np.asarray(pa_ref.fused_tables_ref(
        plan.l2[..., 0], plan.chain_lengths, plan.tenants))
    np.testing.assert_array_equal(derived, np.asarray(tables))
    np.testing.assert_array_equal(np.asarray(plan.lengths),
                                  np.asarray(lengths))
    for i, s in enumerate(sids):
        col = int(plan.lengths[i]) // cfg.block_size
        assert int(plan.write_blocks[i]) == int(derived[i, col])
