"""Unit tests: entry format, chain ops, resolvers, store, streaming."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import format as fmt
from repro.core import chain as chain_lib
from repro.core import metrics, store


def make_store(**kw):
    kw.setdefault("n_pages", 128)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_chain", 16)
    return store.create(**kw)


def test_entry_pack_unpack_roundtrip():
    ptr = jnp.array([0, 1, 12345, fmt.PTR_MASK], jnp.uint32)
    bfi = jnp.array([0, 7, 999, fmt.BFI_MASK], jnp.uint32)
    e = fmt.pack_entry(ptr, bfi, allocated=True, bfi_valid=True)
    np.testing.assert_array_equal(fmt.entry_ptr(e), ptr)
    np.testing.assert_array_equal(fmt.entry_bfi(e), bfi)
    assert bool(jnp.all(fmt.entry_allocated(e)))
    assert bool(jnp.all(fmt.entry_bfi_valid(e)))


def test_unallocated_entry_is_all_zeros():
    e = fmt.pack_entry(123, 5, allocated=False, bfi_valid=True)
    np.testing.assert_array_equal(np.asarray(e), 0)


def test_strip_extension_preserves_vanilla_view():
    e = fmt.pack_entry(42, 9, allocated=True, bfi_valid=True)
    v = fmt.strip_extension(e)
    np.testing.assert_array_equal(fmt.entry_ptr(v), fmt.entry_ptr(e))
    assert not bool(fmt.entry_bfi_valid(v))


def test_write_read_roundtrip():
    ch = make_store()
    ids = jnp.array([0, 3, 127], jnp.int32)
    data = jax.random.normal(jax.random.PRNGKey(0), (3, 8))
    ch = store.write(ch, ids, data)
    for method in ("vanilla", "direct", "auto"):
        out, res = store.read(ch, ids, method=method)
        np.testing.assert_allclose(out, data, rtol=1e-6)
        assert bool(jnp.all(res.found))


def test_unwritten_pages_read_as_zeros():
    ch = make_store()
    out, res = store.read(ch, jnp.array([5, 6], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    assert not bool(jnp.any(res.found))


def test_cow_snapshot_immutability():
    """Writes after a snapshot must not change what the snapshot held."""
    ch = make_store()
    ids = jnp.array([1, 2], jnp.int32)
    d0 = jnp.ones((2, 8))
    ch = store.write(ch, ids, d0)
    before = store.materialize(ch)
    ch = store.snapshot(ch)
    ch = store.write(ch, ids, 2 * d0)
    after, _ = store.read(ch, ids)
    np.testing.assert_allclose(after, 2 * d0)
    # the backing layer's data pool rows were never touched
    owner0 = store.read(ch, ids, method="direct")[1].owner
    np.testing.assert_array_equal(np.asarray(owner0), 1)
    np.testing.assert_allclose(
        np.asarray(before[np.asarray(ids)]), np.asarray(d0), rtol=1e-6
    )


def test_direct_lookups_constant_vanilla_linear():
    ch = make_store()
    ids = jnp.array([7], jnp.int32)
    ch = store.write(ch, ids, jnp.ones((1, 8)))
    for _ in range(6):
        ch = store.snapshot(ch)
    _, res_v = store.read(ch, ids, method="vanilla")
    _, res_d = store.read(ch, ids, method="direct")
    assert int(res_d.lookups[0]) == 1
    # vanilla walks from the active volume down to the owner
    assert int(res_v.lookups[0]) >= 1


def test_vanilla_format_chain_walk_cost():
    ch = make_store(scalable=False)
    ids = jnp.array([7], jnp.int32)
    ch = store.write(ch, ids, jnp.ones((1, 8)))
    for _ in range(6):
        ch = store.snapshot(ch)
    _, res = store.read(ch, ids, method="vanilla")
    assert int(res.lookups[0]) == 7  # owner at layer 0, chain length 7


def test_snapshot_copy_forward_semantics():
    ch = make_store()
    ids = jnp.array([1, 2, 3], jnp.int32)
    ch = store.write(ch, ids, jnp.ones((3, 8)))
    ch = store.snapshot(ch)
    # direct access on the new active volume sees everything with 1 lookup
    _, res = store.read(ch, ids, method="direct")
    assert bool(jnp.all(res.found))
    np.testing.assert_array_equal(np.asarray(res.lookups), 1)
    np.testing.assert_array_equal(np.asarray(res.owner), 0)


def test_stream_preserves_content_and_shortens_chain():
    ch = make_store()
    key = jax.random.PRNGKey(1)
    for i in range(5):
        ids = jax.random.choice(jax.random.fold_in(key, i), 128, (16,),
                                replace=False).astype(jnp.int32)
        data = jax.random.normal(jax.random.fold_in(key, 100 + i), (16, 8))
        ch = store.write(ch, ids, data)
        ch = store.snapshot(ch)
    before = store.materialize(ch)
    for copy_data in (False, True):
        ch2 = store.stream(ch, merge_upto=2, copy_data=copy_data)
        after = store.materialize(ch2)
        np.testing.assert_allclose(np.asarray(before), np.asarray(after),
                                   rtol=1e-6)
        assert int(ch2.length) == int(ch.length) - 2


def test_stream_pool_exhaustion_flags_overflow_not_raise():
    """stream(copy_data=True) on a full pool must follow the write path's
    contract: drop the copy (degrade to a metadata-only merge), set
    ``overflow`` and leave the chain consistent — not unwind mid-op. The
    maintenance scheduler relies on this to skip-and-retry after GC."""
    ch = store.create(n_pages=64, page_size=8, max_chain=4, pool_capacity=16)
    ids = jnp.arange(8, dtype=jnp.int32)
    ch = store.write(ch, ids, jnp.ones((8, 8)))
    ch = store.snapshot(ch)
    ch = store.write(ch, ids, 2 * jnp.ones((8, 8)))   # pool now full
    ch = store.snapshot(ch)
    streamed = store.stream(ch, merge_upto=1, copy_data=True)
    assert bool(streamed.overflow)
    assert int(streamed.length) == 2                  # merge still happened
    np.testing.assert_allclose(
        np.asarray(store.materialize(streamed)),
        np.asarray(store.materialize(ch)), rtol=1e-6)
    # GC then retry: compact_pool clears the flag and makes room
    retried = store.stream(store.compact_pool(streamed), 0, copy_data=True)
    assert not bool(retried.overflow)


def test_stream_copy_data_preserves_stripped_vanilla_image():
    """Regression: the data-copy path must not rewrite the pointers of
    bfi-invalid upper-layer entries. In an image written by a vanilla
    driver the extension word is genuinely zero (``strip_extension``), so
    every allocated entry reads as bfi=0 — which is *not* a reference to
    the merged base. The old code treated it as one and aliased such
    entries onto the base's rewritten rows, resurrecting stale data."""
    ch = make_store(scalable=False)
    ids = jnp.arange(8, dtype=jnp.int32)
    ch = store.write(ch, ids, jnp.ones((8, 8)))
    ch = store.snapshot(ch)
    ch = store.write(ch, ids, 2 * jnp.ones((8, 8)))   # upper layer owns ids
    ch = store.snapshot(ch)
    ch = store.write(ch, jnp.array([30], jnp.int32), jnp.ones((1, 8)))
    # the on-disk vanilla view: reserved word1 bits are all zero
    ch = dataclasses.replace(ch, l2=fmt.strip_extension(ch.l2))
    streamed = store.stream(ch, merge_upto=0, copy_data=True)
    out, _ = store.read(streamed, ids, method="vanilla")
    np.testing.assert_allclose(np.asarray(out), 2.0)  # not the stale 1.0


def test_convert_to_scalable_enables_direct():
    ch = make_store(scalable=False)
    ids = jnp.array([3, 9], jnp.int32)
    ch = store.write(ch, ids, jnp.ones((2, 8)))
    ch = store.snapshot(ch)
    ch = store.write(ch, jnp.array([9], jnp.int32), 2 * jnp.ones((1, 8)))
    # direct on a vanilla chain finds nothing trustworthy
    _, res = store.read(ch, ids, method="direct")
    assert not bool(jnp.all(res.found))
    ch2 = chain_lib.convert_to_scalable(ch)
    out, res2 = store.read(ch2, ids, method="direct")
    assert bool(jnp.all(res2.found))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(store.read(ch, ids, method="vanilla")[0]),
        rtol=1e-6,
    )


def test_snapshot_on_full_chain_caps_and_flags():
    """Snapshotting a chain already at max_chain must not grow it (later
    writes would scatter out of bounds and vanish) — it caps and flags."""
    ch = make_store(max_chain=2)
    ids = jnp.array([1], jnp.int32)
    ch = store.write(ch, ids, jnp.ones((1, 8)))
    ch = store.snapshot(ch)
    assert int(ch.length) == 2 and not bool(ch.snap_dropped)
    ch = store.snapshot(ch)                    # chain is full
    assert int(ch.length) == 2 and bool(ch.snap_dropped)
    assert not bool(ch.overflow)               # pool flag is separate
    ch = store.write(ch, ids, 2 * jnp.ones((1, 8)))
    out, _ = store.read(ch, ids)
    np.testing.assert_allclose(np.asarray(out), 2.0)   # write still lands
    # a no-op stream (merge_upto=0 shortens nothing) keeps the flag latched;
    # a real stream clears it
    ch3 = make_store(max_chain=3)
    ch3 = store.write(ch3, ids, jnp.ones((1, 8)))
    ch3 = store.snapshot(store.snapshot(ch3))
    ch3 = store.snapshot(ch3)                          # dropped
    assert bool(ch3.snap_dropped)
    assert bool(store.stream(ch3, 0).snap_dropped)     # still full
    assert not bool(store.stream(ch3, 1).snap_dropped)  # room made


def test_pool_overflow_flag():
    ch = store.create(n_pages=64, page_size=4, max_chain=4, pool_capacity=8)
    ids = jnp.arange(16, dtype=jnp.int32)
    ch = store.write(ch, ids, jnp.ones((16, 4)))
    with pytest.raises(RuntimeError):
        store.check_pool_capacity(ch)
    # overflow rows are dropped, not clamped: the 8 landed pages keep their
    # data and the excess pages read as unallocated (same contract as fleet)
    out, res = store.read(ch, ids)
    np.testing.assert_array_equal(np.asarray(res.found),
                                  [True] * 8 + [False] * 8)
    np.testing.assert_allclose(np.asarray(out[:8]), 1.0)


def test_eq2_matches_paper_example():
    # paper: 50 GB disk, 64 KB clusters, 8 B entries → ~6 MB per snapshot
    got = metrics.eq2_snapshot_overhead_bytes(50 * 2**30)
    assert abs(got - 6.25 * 2**20) < 0.5 * 2**20


def test_eq1_linear_in_chain_length():
    a = metrics.eq1_average_cost(0.9, 0.05, 0.05, 10)
    b = metrics.eq1_average_cost(0.9, 0.05, 0.05, 1000)
    assert abs(b / a - 100.0) < 1e-6


def test_compact_pool_preserves_reads():
    ch = make_store()
    key = jax.random.PRNGKey(2)
    for i in range(6):
        ids = jax.random.choice(jax.random.fold_in(key, i), 128, (24,),
                                replace=False).astype(jnp.int32)
        ch = store.write(ch, ids,
                         jax.random.normal(jax.random.fold_in(key, 50 + i),
                                           (24, 8)))
        ch = store.snapshot(ch)
    ch = store.stream(ch, merge_upto=3, copy_data=False)
    before = store.materialize(ch)
    compacted = chain_lib.compact_pool(ch)
    after = store.materialize(compacted)
    np.testing.assert_allclose(np.asarray(before), np.asarray(after))
    assert int(compacted.pool_cursor) <= int(ch.pool_cursor)


def test_paper_setup_constants():
    """The paper-setup config reproduces its own §6.5 numbers."""
    from repro.configs.paper_chain import SETUP, headline_claims

    assert SETUP.l2_cache_bytes_full(50 * 2**30) == 6_553_600  # 6.25 MiB
    got = metrics.eq2_snapshot_overhead_bytes(
        50 * 2**30, SETUP.cluster_bytes, SETUP.l2_entry_bytes, 0)
    claims = headline_claims()
    assert abs(got - claims["snapshot_overhead_bytes_50gb"]) / got < 0.1
