"""Serving-plane benchmark: fleet-backed decode-step prep vs host numpy.

``Engine.step()`` spends its non-model time materializing block tables
and COW-preparing write slots. This benchmark times exactly that half of
the step, per (fork format × fork depth) cell, over a batch of live
leaves at the bottom of a fork chain:

* ``host``  — the seed engine's data path: TWO numpy chain walks per
  sequence per step (one for the COW-prepare decision, one for the
  table), assembled on the host (``PagedKVCache._resolve_oracle``);
* ``fleet`` — ``PagedKVCache.prepare_step``: ONE stacked fleet resolve
  for the whole batch (``resolve_*_stacked`` — the Pallas kernel plane
  on lane-aligned pools, the vmapped gather otherwise), one batched COW
  stamp, one stacked host→device transfer.

The chain is built by fork→append→retire-parent rounds, so a depth-*d*
cell resolves through *d* tombstoned ancestors — the paper's Eq. 1
regime (vanilla cost grows with depth, scalable stays O(1)). Both paths
run on an identical settled cache and the produced tables are verified
bit-identical per cell before timing.

A second section, ``decode``, measures the whole serving step end to
end: two engines over a tiny one-layer model decode the same fork-chain
workload, one with ``decode_path="tables"`` (stacked resolve → padded
tables → transfer) and one with ``decode_path="fused"`` (narrow
COW-prepare resolve, chain walk inside the attention plane, zero table
materialization). Each cell is token- and table-verified before timing.

Run: ``PYTHONPATH=src python benchmarks/serve.py --json BENCH_serve.json``
(see ``docs/benchmarks.md`` for the JSON schema).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json, time_fn
except ModuleNotFoundError:  # invoked as `python benchmarks/serve.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json, time_fn
from repro.configs import smoke_config
from repro.kernels.paged_attention import ref as pa_ref
from repro.kvcache.paged import PagedKVCache, PagedKVConfig
from repro.models.api import get_model
from repro.serve.engine import Engine


def build_forked_cache(depth: int, *, scalable: bool, batch: int,
                       block_size: int, max_blocks: int, n_blocks: int,
                       resolver: str, prompt_tokens: int = 64):
    """A cache with ``batch`` live leaves under a fork chain of ``depth``
    retired ancestors, every generation owning one divergent token."""
    cfg = PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=8,
                        block_size=block_size, n_blocks=n_blocks,
                        max_blocks_per_seq=max_blocks, dtype=jnp.float32)
    kv = PagedKVCache(cfg, scalable=scalable, resolver=resolver)
    one = jnp.ones((1, 1, 1, 8), jnp.float32)

    sid = kv.new_seq()
    k = jnp.ones((1, prompt_tokens, 1, 8), jnp.float32)
    kv.append_prefill(sid, k, k)
    for _ in range(depth):
        child = kv.fork(sid)
        kv.append(child, one[:, 0], one[:, 0])   # each layer owns a block
        kv.free_seq(sid)                         # tombstone the ancestor
        sid = child
    leaves = [sid]
    for _ in range(batch - 1):
        leaf = kv.fork(sid)
        kv.append(leaf, one[:, 0], one[:, 0])
        leaves.append(leaf)
    return kv, sorted(leaves)


def host_step_prep(kv: PagedKVCache, sids, pad_to: int, pad_block: int):
    """The seed engine's step prep: per-sequence host walks + host-side
    assembly. One walk decides the COW-prepare (a no-op on the settled
    cache, exactly like the fleet path's), one materializes the table."""
    bs = kv.cfg.block_size
    for sid in sids:
        seq = kv._seqs[sid]
        blk = seq.length // bs
        table, owner, _ = kv._resolve_oracle(sid)          # prepare walk
        assert table[blk] >= 0 and seq.owner[blk] == sid   # settled: no-op
    n = max(len(sids), pad_to)
    tables = np.full((n, kv.cfg.max_blocks_per_seq), pad_block, np.int32)
    lengths = np.zeros(n, np.int32)
    for i, sid in enumerate(sids):
        table, _, _ = kv._resolve_oracle(sid)              # table walk
        tables[i] = np.where(table >= 0, table, pad_block)
        lengths[i] = kv._seqs[sid].length
    return jnp.asarray(tables), jnp.asarray(lengths)


def bench_cell(depth: int, scalable: bool, args) -> dict:
    kv, sids = build_forked_cache(
        depth, scalable=scalable, batch=args.batch,
        block_size=args.block_size, max_blocks=args.blocks_per_seq,
        n_blocks=args.n_blocks, resolver=args.resolver,
    )
    pad_block = kv.reserve_block()
    pad_to = 1
    while pad_to < len(sids):
        pad_to *= 2

    # settle: every leaf's write slot gets prepared once, so both timed
    # paths are pure reads over identical state
    fleet_fn = lambda: kv.prepare_step(sids, pad_to=pad_to,
                                       pad_block=pad_block)
    host_fn = lambda: host_step_prep(kv, sids, pad_to, pad_block)
    f_tables, f_lengths = fleet_fn()
    h_tables, h_lengths = host_fn()
    np.testing.assert_array_equal(np.asarray(f_tables), np.asarray(h_tables))
    np.testing.assert_array_equal(np.asarray(f_lengths), np.asarray(h_lengths))

    t_fleet = time_fn(fleet_fn, warmup=1, iters=args.iters)
    t_host = time_fn(host_fn, warmup=1, iters=args.iters)
    fmt_name = "scalable" if scalable else "vanilla"
    emit(f"serve_step_{fmt_name}_depth{depth}", t_fleet * 1e6,
         f"host_us={t_host * 1e6:.0f};fleet_us={t_fleet * 1e6:.0f};"
         f"speedup={t_host / t_fleet:.2f}x;batch={len(sids)}")
    return dict(
        section="serve_step",
        format=fmt_name,
        depth=depth,
        batch=len(sids),
        resolver=args.resolver,
        host_us=t_host * 1e6,
        fleet_us=t_fleet * 1e6,
        speedup=t_host / t_fleet,
        verified=True,
    )


def build_forked_engine(depth: int, *, scalable: bool, decode_path: str,
                        cfg, params, args) -> Engine:
    """An engine whose batch sits under a fork chain of ``depth`` retired
    ancestors — the engine-level twin of ``build_forked_cache``. Both
    decode paths get byte-identical construction (same RNG, same op
    order), so their pools and fleet indices match bit for bit."""
    eng = Engine(cfg, params, scalable=scalable, n_blocks=args.n_blocks,
                 block_size=args.block_size, max_blocks_per_seq=128,
                 resolver="gather", decode_path=decode_path)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, size=31)
    sid = eng.add_request(np.asarray(prompt))
    one = jnp.asarray(
        rng.standard_normal((cfg.n_layers, cfg.n_kv_heads, cfg.hd)),
        jnp.float32)
    for _ in range(depth):
        child = eng.fork_request(sid)
        eng.kv.append(child, one, one)      # each generation diverges
        eng.finish_request(sid)             # tombstone the ancestor
        sid = child
    for _ in range(args.batch - 1):
        leaf = eng.fork_request(sid)
        eng.kv.append(leaf, one, one)
    return eng


def verify_decode_cell(eng_t: Engine, eng_f: Engine) -> None:
    """Bit-verify a decode cell before timing it: the fused walk oracle
    must reproduce the host chain-walk oracle's tables for every live
    sequence, and one full step on each engine must emit identical
    tokens and leave identical allocation."""
    kv = eng_f.kv
    sids = sorted(eng_f.active)
    tenants = jnp.asarray([kv._seqs[s].tenant for s in sids], jnp.int32)
    fused = np.asarray(pa_ref.fused_tables_ref(
        kv.fleet.l2[..., 0], kv.fleet.length, tenants))
    for i, sid in enumerate(sids):
        table, _, _ = kv._resolve_oracle(sid)
        np.testing.assert_array_equal(fused[i], table)
    out_t, out_f = eng_t.step(), eng_f.step()
    assert list(out_t.values()) == list(out_f.values()), (
        f"fused decode diverged from tables decode: {out_t} vs {out_f}")
    assert eng_t.kv.blocks_in_use() == eng_f.kv.blocks_in_use()


def bench_decode_cell(depth: int, scalable: bool, cfg, params,
                      args) -> dict:
    build = lambda path: build_forked_engine(
        depth, scalable=scalable, decode_path=path, cfg=cfg, params=params,
        args=args)
    eng_t, eng_f = build("tables"), build("fused")
    verify_decode_cell(eng_t, eng_f)
    t_tables = time_fn(eng_t.step, warmup=1, iters=args.iters)
    t_fused = time_fn(eng_f.step, warmup=1, iters=args.iters)
    fmt_name = "scalable" if scalable else "vanilla"
    emit(f"decode_{fmt_name}_depth{depth}", t_fused * 1e6,
         f"tables_us={t_tables * 1e6:.0f};fused_us={t_fused * 1e6:.0f};"
         f"speedup={t_tables / t_fused:.2f}x;batch={len(eng_f.active)}")
    return dict(
        section="decode",
        format=fmt_name,
        depth=depth,
        batch=len(eng_f.active),
        resolver="gather",
        tables_us=t_tables * 1e6,
        fused_us=t_fused * 1e6,
        speedup=t_tables / t_fused,
        verified=True,
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--depths", type=int, nargs="+", default=[1, 64, 500],
                    help="fork depths (paper regime: 1, 64, 500)")
    ap.add_argument("--batch", type=int, default=8,
                    help="live leaf sequences per decode step")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--blocks-per-seq", type=int, default=64)
    ap.add_argument("--n-blocks", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--resolver", default="auto",
                    help="fleet resolver method (see fleet.get_resolver)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: small batch, few timing iters")
    ap.add_argument("--json", metavar="PATH",
                    help="write a BENCH_serve.json artifact")
    args = ap.parse_args()
    if args.smoke:
        args.batch = min(args.batch, 4)
        args.iters = min(args.iters, 3)

    results = []
    for depth in args.depths:
        for scalable in (False, True):
            results.append(bench_cell(depth, scalable, args))
    # end-to-end decode: tables path vs fused path over a tiny model
    cfg = dataclasses.replace(smoke_config("qwen2-7b"), n_layers=1)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    for depth in args.depths:
        for scalable in (False, True):
            results.append(bench_decode_cell(depth, scalable, cfg, params,
                                             args))
    for r in results:
        if r["depth"] >= 64 and r["format"] == "vanilla":
            if r["section"] == "serve_step":
                assert r["speedup"] > 1.0, (
                    "fleet-backed prep lost to host numpy at depth "
                    f"{r['depth']}"
                )
            elif r["depth"] >= 500:
                assert r["speedup"] > 1.0, (
                    "fused decode lost to the tables path at depth "
                    f"{r['depth']}"
                )
    if args.json:
        emit_json(
            args.json, "serve", results,
            batch=args.batch, block_size=args.block_size,
            blocks_per_seq=args.blocks_per_seq, resolver=args.resolver,
        )


if __name__ == "__main__":
    main()
