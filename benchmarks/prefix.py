"""Golden-prefix dedup benchmark: shared-prefix admission as a COW fork.

Serving fleets concentrate on a handful of prompt templates: thousands
of concurrent sequences share one of a few long system prefixes and
diverge only in a short user suffix. The seed engine prefills every
admission from scratch — N sequences over 4 templates store the shared
prefix N times and pay the full prefill each admission. The golden
registry turns both costs into fork costs: the template is prefilled
ONCE, frozen under a content hash, and every admission COW-forks it and
prefills only the suffix (one chunked ``paged_suffix_prefill`` dispatch).

Two sections, each cell bit-verified against the dedup-free path before
any number is recorded:

* ``capacity`` — KV-plane residency at N live sequences over ≤4 shared
  prefixes: blocks-in-use with the golden registry vs a baseline cache
  holding the same N sequences with duplicated storage. EVERY sequence's
  gathered K/V is verified bitwise equal across the two caches (numpy
  gather oracle over the resolved tables), so the ratio compares
  identical logical content.
* ``ttft`` — engine-plane admission latency (time-to-first-token) while
  filling to N concurrent sequences: golden-fork admission vs full
  prefill, tiny one-layer model. Before timing, one fork per prefix is
  verified bitwise against a *duplicate-storage oracle*: the golden's
  gathered bytes are re-stored under a fresh sequence and the SAME
  chunked suffix dispatch runs over the copy — identical pool reads,
  identical logits, identical stored suffix, deterministically. First
  tokens against the real full-prefill baseline are reported as
  ``token_agreement`` (informational: prefill and chunked suffix use
  different matmul shapes, so those logits are close, not bitwise).

Run: ``PYTHONPATH=src python benchmarks/prefix.py --json BENCH_prefix.json``
(see ``docs/benchmarks.md`` for the JSON schema).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json
except ModuleNotFoundError:  # invoked as `python benchmarks/prefix.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json
from repro.configs import smoke_config
from repro.kvcache.paged import PagedKVCache, PagedKVConfig
from repro.models.api import get_model
from repro.serve.engine import Engine

BLOCK_SIZE = 4


def _np_gather(kv: PagedKVCache, pool_k: np.ndarray, pool_v: np.ndarray,
               sid: int):
    """Host gather oracle: a sequence's (L, T, H, D) K/V read off numpy
    pool snapshots through the resolved table — cheap enough to verify
    every sequence in the cell, not a sample."""
    bs = kv.cfg.block_size
    n = kv.seq_length(sid)
    table, _, _ = kv._resolve_oracle(sid)
    nblk = -(-n // bs)
    blocks = np.asarray(table[:nblk])
    assert np.all(blocks >= 0)
    shape = (pool_k.shape[0], nblk * bs) + pool_k.shape[3:]
    return (pool_k[:, blocks].reshape(shape)[:, :n],
            pool_v[:, blocks].reshape(shape)[:, :n])


def bench_capacity(scalable: bool, args) -> dict:
    """KV-plane residency: N sequences over ≤4 shared prefixes, golden
    forks vs duplicated storage, every sequence verified bitwise."""
    n, npfx = args.n_seqs, args.n_prefixes
    pt, st = args.prefix_tokens, args.suffix_tokens
    pfx_blocks = -(-pt // BLOCK_SIZE)
    seq_blocks = -(-(pt + st) // BLOCK_SIZE)

    def mk(n_blocks: int) -> PagedKVCache:
        cfg = PagedKVConfig(
            n_layers=1, n_kv_heads=1, head_dim=8, block_size=BLOCK_SIZE,
            n_blocks=n_blocks, max_blocks_per_seq=seq_blocks + 2,
            dtype=jnp.float32)
        return PagedKVCache(cfg, scalable=scalable, resolver="gather")

    def kv_data(seed: int, tokens: int):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.standard_normal((1, tokens, 1, 8)),
                            jnp.float32),
                jnp.asarray(r.standard_normal((1, tokens, 1, 8)),
                            jnp.float32))

    prefixes = [kv_data(7 + p, pt) for p in range(npfx)]
    suffixes = [kv_data(1000 + i, st) for i in range(n)]

    dedup = mk(npfx * pfx_blocks + n * (seq_blocks - pfx_blocks) + 64)
    goldens = []
    for pk, pv in prefixes:
        g = dedup.new_seq()
        dedup.append_prefill(g, pk, pv)
        dedup.register_golden(g)
        goldens.append(g)
    dsids = []
    for i, (sk, sv) in enumerate(suffixes):
        sid = dedup.fork(goldens[i % npfx])
        dedup.append_prefill(sid, sk, sv)
        dsids.append(sid)

    base = mk(n * seq_blocks + 64)
    bsids = []
    for i, (sk, sv) in enumerate(suffixes):
        pk, pv = prefixes[i % npfx]
        sid = base.new_seq()
        base.append_prefill(sid, jnp.concatenate([pk, sk], axis=1),
                            jnp.concatenate([pv, sv], axis=1))
        bsids.append(sid)

    # bit-verify EVERY sequence: the dedup cache must serve the exact
    # bytes the duplicate-storage cache holds
    dk, dv = np.asarray(dedup.pool_k), np.asarray(dedup.pool_v)
    bk, bv = np.asarray(base.pool_k), np.asarray(base.pool_v)
    for ds, bs_ in zip(dsids, bsids):
        k0, v0 = _np_gather(dedup, dk, dv, ds)
        k1, v1 = _np_gather(base, bk, bv, bs_)
        assert np.array_equal(k0, k1) and np.array_equal(v0, v1), (
            f"dedup sequence {ds} diverged from its duplicate-storage twin")
    # cross-check the host oracle against the cache's device gather once
    gk, gv = dedup.gather(dsids[0])
    k0, v0 = _np_gather(dedup, dk, dv, dsids[0])
    assert np.array_equal(np.asarray(gk), k0)
    assert np.array_equal(np.asarray(gv), v0)

    ded_blocks = dedup.blocks_in_use()
    base_blocks = base.blocks_in_use()
    ratio = base_blocks / ded_blocks
    stats = dedup.golden_stats()
    fmt_name = "scalable" if scalable else "vanilla"
    emit(f"prefix_capacity_{fmt_name}", ded_blocks,
         f"baseline_blocks={base_blocks};dedup_blocks={ded_blocks};"
         f"ratio={ratio:.1f}x;saved={stats['dedup_blocks_saved']}")
    return dict(
        section="capacity",
        format=fmt_name,
        n_seqs=n,
        n_prefixes=npfx,
        prefix_tokens=pt,
        suffix_tokens=st,
        dedup_blocks=ded_blocks,
        baseline_blocks=base_blocks,
        blocks_ratio=ratio,
        golden_blocks_shared=stats["golden_blocks_shared"],
        dedup_blocks_saved=stats["dedup_blocks_saved"],
        verified=True,
    )


def bench_ttft(scalable: bool, cfg, params, args) -> dict:
    """Engine-plane admission latency while filling to N concurrent
    sequences: golden-fork + chunked suffix prefill vs full prefill."""
    n, npfx = args.n_concurrent, args.n_prefixes
    pt, st = args.prefix_tokens, args.suffix_tokens
    pfx_blocks = -(-pt // BLOCK_SIZE)
    seq_blocks = -(-(pt + st) // BLOCK_SIZE)
    rng = np.random.default_rng(3)
    prefixes = [rng.integers(0, cfg.vocab_size, pt).tolist()
                for _ in range(npfx)]

    def mk(n_blocks: int, **kw) -> Engine:
        return Engine(cfg, params, scalable=scalable, n_blocks=n_blocks,
                      block_size=BLOCK_SIZE, max_blocks_per_seq=seq_blocks + 8,
                      resolver="gather", decode_path="tables", **kw)

    # the dedup pool holds each prefix once; the baseline pool must hold
    # it once PER SEQUENCE — each engine is sized to its own workload
    ded = mk(npfx * pfx_blocks + 4 * n + 256)
    gsids = [ded.register_golden(np.asarray(p, np.int32)) for p in prefixes]
    base = mk(n * (seq_blocks + 2) + 256)

    def admit(eng: Engine, i: int, suffix=None) -> int:
        suffix = suffix or rng.integers(0, cfg.vocab_size, st).tolist()
        return eng.add_request(
            np.asarray(prefixes[i % npfx] + suffix, np.int32))

    # bit-verify one fork per prefix against the duplicate-storage
    # oracle; collect informational token agreement vs the real baseline
    agree = checks = 0
    for pi in range(npfx):
        suffix = rng.integers(0, cfg.vocab_size, st).tolist()
        sid = admit(ded, pi, suffix)
        tok = ded.active[sid][0]
        gk, gv = ded.kv.gather(gsids[pi])
        osid = ded.kv.new_seq()
        ded.kv.append_prefill(osid, gk, gv)          # duplicate the storage
        otok = ded._suffix_prefill(osid, suffix)     # the SAME chunked jit
        fk, fv = ded.kv.gather(sid)
        ok_, ov_ = ded.kv.gather(osid)
        assert np.array_equal(np.asarray(fk), np.asarray(ok_))
        assert np.array_equal(np.asarray(fv), np.asarray(ov_))
        assert tok == otok, (
            f"fork admission token {tok} != duplicate-storage oracle {otok}")
        ded.kv.free_seq(osid)
        bsid = admit(base, pi, suffix)
        agree += int(base.active[bsid][0] == tok)
        checks += 1

    # warm past jit compiles and the early fleet-growth recompile waves,
    # then time admissions on the way to n concurrent
    for i in range(args.warm):
        admit(ded, i)
        admit(base, i)
    n_timed = n - args.warm - npfx
    t0 = time.perf_counter()
    for i in range(n_timed):
        admit(ded, i)
    jax.block_until_ready(ded.kv.pool_k)
    t_ded = (time.perf_counter() - t0) / n_timed
    t0 = time.perf_counter()
    for i in range(n_timed):
        admit(base, i)
    jax.block_until_ready(base.kv.pool_k)
    t_base = (time.perf_counter() - t0) / n_timed

    stats = ded.memory_stats()
    fmt_name = "scalable" if scalable else "vanilla"
    emit(f"prefix_ttft_{fmt_name}", t_ded * 1e6,
         f"baseline_us={t_base * 1e6:.0f};dedup_us={t_ded * 1e6:.0f};"
         f"speedup={t_base / t_ded:.2f}x;concurrent={len(ded.active)}")
    return dict(
        section="ttft",
        format=fmt_name,
        n_concurrent=len(ded.active),
        n_prefixes=npfx,
        prefix_tokens=pt,
        suffix_tokens=st,
        dedup_admit_ms=t_ded * 1e3,
        baseline_admit_ms=t_base * 1e3,
        speedup=t_base / t_ded,
        token_agreement=agree / checks,
        golden_hits=stats["golden_hits"],
        dedup_blocks_saved=stats["dedup_blocks_saved"],
        verified=True,
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-seqs", type=int, default=1024,
                    help="capacity section: live sequences per cell")
    ap.add_argument("--n-concurrent", type=int, default=1024,
                    help="ttft section: concurrent sequences to fill to")
    ap.add_argument("--n-prefixes", type=int, default=4)
    ap.add_argument("--prefix-tokens", type=int, default=256)
    ap.add_argument("--suffix-tokens", type=int, default=4)
    ap.add_argument("--warm", type=int, default=40,
                    help="untimed admissions per engine before timing")
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: small sequence counts, short warmup")
    ap.add_argument("--json", metavar="PATH",
                    help="write a BENCH_prefix.json artifact")
    args = ap.parse_args()
    if args.smoke:
        args.n_seqs = min(args.n_seqs, 64)
        args.n_concurrent = min(args.n_concurrent, 32)
        args.warm = min(args.warm, 8)

    results = []
    for scalable in (False, True):
        results.append(bench_capacity(scalable, args))
    cfg = dataclasses.replace(smoke_config("qwen2-7b"), n_layers=1)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    for scalable in (False, True):
        results.append(bench_ttft(scalable, cfg, params, args))

    for r in results:
        if r["section"] == "capacity":
            assert r["blocks_ratio"] >= 5.0, (
                f"dedup saved less than 5x blocks: {r['blocks_ratio']:.1f}x "
                f"({r['format']})")
        elif not args.smoke:
            # smoke cells are too small for a stable latency contrast;
            # the full run must show the admission win
            assert r["speedup"] > 1.0, (
                f"golden-fork admission lost to full prefill: "
                f"{r['speedup']:.2f}x ({r['format']})")
    if args.json:
        emit_json(
            args.json, "prefix", results,
            n_prefixes=args.n_prefixes, prefix_tokens=args.prefix_tokens,
            suffix_tokens=args.suffix_tokens, block_size=BLOCK_SIZE,
        )


if __name__ == "__main__":
    main()
