"""Roofline table from the dry-run manifests (deliverable g).

Reads ``results/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
prints, per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction. MODEL_FLOPS
is recomputed from the configs (6·N_active·D train / 2·N_active·D
inference) so config fixes don't require recompiling.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HW


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    mult = 6.0 if spec.kind == "train" else 2.0
    return mult * cfg.active_param_count() * tokens


def load_records(out_dir: str = "results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(f))
        mf = model_flops(r["arch"], r["shape"]) / r["n_devices"]
        t = r["roofline_terms_s"]
        dom = max(t.values())
        r["model_flops_per_device"] = mf
        r["useful_flops_ratio"] = mf / max(r["flops_per_device"], 1.0)
        r["roofline_frac"] = (mf / HW["peak_flops_bf16"]) / dom if dom else 0.0
        recs.append(r)
    return recs


def main(out_dir: str = "results/dryrun"):
    recs = load_records(out_dir)
    if not recs:
        print("roofline,0,no dry-run manifests found (run repro.launch.dryrun)")
        return
    for r in recs:
        t = r["roofline_terms_s"]
        print(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0.00,"
            f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
            f"collective_s={t['collective_s']:.4f};bottleneck={r['bottleneck']};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"roofline_frac={r['roofline_frac']:.4f};"
            f"peak_GiB={r['memory']['peak_bytes_per_device']/2**30:.2f}"
        )


if __name__ == "__main__":
    main()
