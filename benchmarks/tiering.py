"""Tiered-pool benchmark: live tenants at a fixed HBM budget (paper §6.3).

The paper's scalable format keeps every snapshot layer's clusters in the
backing store; at fleet granularity the analogous pressure is HBM — a
depth-D chain pins ~D layers' worth of pool rows even though only the
active COW layer is ever written. The ``TieredStore`` spills those frozen
layers to host memory. This benchmark measures what that buys at a fixed
device-pool budget, for depths {64, 500}:

* **capacity** — tenants are admitted in waves; each wave builds its
  depth-D chain (write + snapshot per layer). ``baseline`` admits into a
  plain fleet until the allocator overflows; ``tiered`` runs a
  ``MaintenanceScheduler`` demotion policy between steps, so frozen
  layers spill and the next wave fits. ``tenants_live`` is the number of
  fully-built, never-overflowed chains each mode sustains — the headline
  is the tiered/baseline ratio (acceptance: >= 4x at depth 500).
* **worst-tick latency** — every scheduler tick during the tiered run is
  timed; budgeted demotion (``demote_rows_per_tick``) should keep the
  worst tick far below ``stw_demote_ms``, the cost of spilling the whole
  fleet in one stop-the-world transfer (measured on the baseline fleet).
* **bit-verification** — every cell replays the writes into a numpy
  shadow and requires ``fleet.read_tiered`` (tiered) / ``fleet.read``
  (baseline, and tiered again after promoting a wave back) to match it
  bit-for-bit, so the capacity numbers can never come from dropped data.

Emits ``BENCH_tiering.json``.

Run: ``PYTHONPATH=src python benchmarks/tiering.py``
CI smoke: ``python benchmarks/tiering.py --smoke``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json
except ModuleNotFoundError:  # invoked as `python benchmarks/tiering.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json
from repro.core import fleet as fleet_lib
from repro.core import metrics
from repro.core.scheduler import MaintenanceScheduler
from repro.core.store import TieredStore

_QUANTUM = 10


def make_spec(n_tenants: int, depth: int, *, wave: int,
              n_pages: int, page_size: int) -> fleet_lib.FleetSpec:
    """Pool sized to hold ONE wave of depth-``depth`` chains (plus two
    quanta of slack) — the fixed device budget both modes run under."""
    per_tenant_q = -(-depth // _QUANTUM)
    return fleet_lib.FleetSpec(
        n_tenants=n_tenants,
        n_pages=n_pages,
        page_size=page_size,
        max_chain=depth + 1,
        pool_capacity=(wave * per_tenant_q + 2) * _QUANTUM,
        lease_quantum=_QUANTUM,
        l2_per_table=n_pages,
        slice_len=1,
    )


def _overflowed(fl) -> int:
    return int(np.sum(np.asarray(fl.overflow)))


def build_waves(spec, *, depth: int, wave: int, sched=None,
                tick_every: int = 16):
    """Admit tenants wave by wave, building each wave's depth-``depth``
    chain layer by layer (one masked write + snapshot per layer). With a
    scheduler, its demotion policy ticks every ``tick_every`` layers and
    drains between waves. Stops at the first overflow. Returns
    ``(fleet, live_tenants, shadow, tick_latencies)`` where ``shadow`` is
    the expected top-layer content per page (numpy, the bit-verification
    reference) and ``live_tenants`` counts fully-built clean chains."""
    fl = fleet_lib.create(spec)
    shadow = np.zeros((spec.n_pages, spec.page_size), np.float32)
    written = np.zeros(spec.n_pages, bool)
    lat: list[float] = []
    live = 0

    def tick():
        t0 = time.perf_counter()
        sched.tick()
        jax.block_until_ready(sched.fleet.l1)
        lat.append(time.perf_counter() - t0)

    for start in range(0, spec.n_tenants, wave):
        members = list(range(start, min(start + wave, spec.n_tenants)))
        mask = np.zeros(spec.n_tenants, bool)
        mask[members] = True
        jmask = jnp.asarray(mask)
        for layer in range(depth):
            pid = layer % spec.n_pages
            ids = jnp.full((spec.n_tenants, 1), pid, jnp.int32)
            data = jnp.full((spec.n_tenants, 1, spec.page_size),
                            float(layer + 1), jnp.float32)
            fl = fleet_lib.write(fl, ids, data, mask=jmask)
            fl = fleet_lib.snapshot(fl, mask=jmask)
            if sched is not None:
                sched.fleet = fl
                if (layer + 1) % tick_every == 0:
                    tick()
                fl = sched.fleet
        if start == 0:   # identical for every wave: last write of a page wins
            for layer in range(depth):
                shadow[layer % spec.n_pages] = float(layer + 1)
                written[layer % spec.n_pages] = True
        if sched is not None:
            sched.fleet = fl
            while True:   # drain: spill everything frozen before admitting
                tick()
                if sched._over_budget(fleet_lib.tenant_stats(sched.fleet)) == 0:
                    break
                if not sched._demote_candidates(
                        fleet_lib.tenant_stats(sched.fleet)):
                    break
            fl = sched.fleet
        if _overflowed(fl):
            break        # this wave did not fit: its partial chains don't count
        live = start + len(members)
    return fl, live, (shadow, written), lat


def _verify_cell(name: str, data, found, live: int, shadow) -> None:
    """Bit-compare resolved top-layer reads of every live tenant against
    the replayed write shadow. Raises — a capacity number that lost data
    must never make it into the artifact."""
    expect, written = shadow
    data = np.asarray(data)
    found = np.asarray(found)
    for t in range(live):
        if not np.array_equal(found[t], written):
            raise AssertionError(f"{name}: tenant {t} allocation map wrong")
        got = data[t][written]
        if not np.array_equal(got.view(np.uint8),
                              expect[written].view(np.uint8)):
            raise AssertionError(f"{name}: tenant {t} content mismatch")


def bench_cell(depth: int, *, n_tenants: int, wave: int, n_pages: int,
               page_size: int, rows_per_tick: int,
               tick_every: int) -> list[dict]:
    spec = make_spec(n_tenants, depth, wave=wave, n_pages=n_pages,
                     page_size=page_size)
    grid = jnp.broadcast_to(jnp.arange(n_pages, dtype=jnp.int32)[None],
                            (n_tenants, n_pages))
    out = []

    # --- baseline: all-HBM, admit until the allocator overflows ------------
    fl, live_b, shadow, _ = build_waves(spec, depth=depth, wave=wave)
    data, res = fleet_lib.read(fl, grid)
    _verify_cell(f"baseline d{depth}", data,
                 np.asarray(res.found) & ~np.asarray(res.zero),
                 live_b, shadow)
    # stop-the-world contrast: spill the whole baseline fleet in one go
    t0 = time.perf_counter()
    _, rep = fleet_lib.demote_tenants(fl, TieredStore.for_fleet(spec),
                                      list(range(n_tenants)))
    stw_ms = (time.perf_counter() - t0) * 1e3
    out.append(dict(
        mode="baseline", depth=depth, tenants_live=live_b,
        pool_rows=spec.pool_capacity, page_size=page_size,
        worst_tick_ms=None, mean_tick_ms=None, ticks=0,
        rows_demoted=0, rows_promoted=0, host_rows=0,
        stw_demote_ms=stw_ms, stw_rows=rep["rows_demoted"],
        verified=True,
    ))
    emit(f"tier_baseline_d{depth}", stw_ms * 1e3,
         f"live={live_b};pool={spec.pool_capacity}")

    # --- tiered: scheduler demotion policy under the same pool -------------
    store = TieredStore.for_fleet(spec)
    sched = MaintenanceScheduler(
        fleet_lib.create(spec),
        stream_chain_threshold=10**9,     # isolate the demotion policy
        store=store, device_page_budget=0,
        demote_rows_per_tick=rows_per_tick,
    )
    fl, live_t, shadow, lat = build_waves(spec, depth=depth, wave=wave,
                                          sched=sched, tick_every=tick_every)
    data, res = fleet_lib.read_tiered(fl, store, grid)
    _verify_cell(f"tiered d{depth}", data,
                 np.asarray(res.found) & ~np.asarray(res.zero),
                 live_t, shadow)
    # promote one wave back and verify the device-resident read too
    back = list(range(min(wave, live_t)))
    t0 = time.perf_counter()
    fl, _ = fleet_lib.promote_tenants(fl, store, back)
    promote_ms = (time.perf_counter() - t0) * 1e3
    hot, hres = fleet_lib.read(fl, grid)
    _verify_cell(f"promoted d{depth}", hot,
                 np.asarray(hres.found) & ~np.asarray(hres.zero),
                 len(back), shadow)
    resid = metrics.tier_residency(fl, store)
    rec = dict(
        mode="tiered", depth=depth, tenants_live=live_t,
        pool_rows=spec.pool_capacity, page_size=page_size,
        worst_tick_ms=max(lat) * 1e3, mean_tick_ms=float(np.mean(lat)) * 1e3,
        ticks=len(lat), rows_demoted=resid.demoted_rows,
        rows_promoted=resid.promoted_rows, host_rows=resid.host_rows,
        stw_demote_ms=stw_ms, promote_wave_ms=promote_ms,
        ratio_vs_baseline=live_t / max(live_b, 1),
        verified=True,
    )
    out.append(rec)
    emit(f"tier_tiered_d{depth}", rec["worst_tick_ms"] * 1e3,
         f"live={live_t};ratio={rec['ratio_vs_baseline']:.1f};"
         f"host_rows={resid.host_rows}")
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--depths", type=int, nargs="+", default=[64, 500])
    p.add_argument("--tenants", type=int, default=32)
    p.add_argument("--wave", type=int, default=4,
                   help="tenants admitted (and chains built) per wave")
    p.add_argument("--pages", type=int, default=64)
    p.add_argument("--page-size", type=int, default=32)
    p.add_argument("--rows-per-tick", type=int, default=256,
                   help="scheduler demotion budget per tick")
    p.add_argument("--tick-every", type=int, default=16,
                   help="build layers between in-band scheduler ticks")
    p.add_argument("--json", default="BENCH_tiering.json",
                   help="output artifact path ('' disables)")
    p.add_argument("--smoke", action="store_true",
                   help="small CI configuration (depth 500 stays in — the "
                        "acceptance ratio is measured there)")
    args = p.parse_args(argv)
    if args.smoke:
        args.tenants, args.page_size, args.pages = 24, 8, 64

    results, ok = [], True
    for d in args.depths:
        cell = bench_cell(
            d, n_tenants=args.tenants, wave=args.wave, n_pages=args.pages,
            page_size=args.page_size, rows_per_tick=args.rows_per_tick,
            tick_every=args.tick_every,
        )
        results.extend(cell)
        tiered = next(r for r in cell if r["mode"] == "tiered")
        if d >= 500 and tiered["ratio_vs_baseline"] < 4:
            ok = False
            print(f"WARNING: depth-{d} tiered/baseline live-tenant ratio "
                  f"{tiered['ratio_vs_baseline']:.1f} below the 4x target")
    if args.json:
        emit_json(args.json, "tiering", results, tenants=args.tenants,
                  wave=args.wave, rows_per_tick=args.rows_per_tick)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
