"""Benchmark harness: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Figures map per DESIGN.md §7.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import paper_figs, roofline, serving

    print("name,us_per_call,derived")
    failures = 0
    for fn in paper_figs.ALL + serving.ALL:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    roofline.main()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
