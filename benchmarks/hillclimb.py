import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: lower+compile named variants of the three chosen
cells and record their roofline terms (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb --cell A --variant base
    PYTHONPATH=src python -m benchmarks.hillclimb --list
"""

import argparse
import json

CELLS = {
    # most collective-bound cell (largest absolute collective term)
    "A": ("qwen2-72b", "train_4k"),
    # worst substantive roofline fraction (SSM recurrence traffic)
    "B": ("rwkv6-3b", "train_4k"),
    # most representative of the paper's technique (KV-cache state mgmt)
    "C": ("qwen2-72b", "decode_32k"),
    # bonus: MoE dispatch efficiency (lowest useful-flops ratio in the table)
    "D": ("qwen2-moe-a2.7b", "train_4k"),
    # bonus: biggest prefill cell
    "E": ("qwen2-72b", "prefill_32k"),
}

VARIANTS = {
    "base": {},
    "bf16cast": dict(cast_bf16=True),
    "gradpin": dict(),  # grad_shardings now default; "base_nopin" disables
    "base_nopin": dict(no_grad_pin=True),
    "sp": dict(seq_shard=True),
    "bf16_sp": dict(cast_bf16=True, seq_shard=True),
    "bf16_sp_accum4": dict(cast_bf16=True, seq_shard=True, accum=4),
    "bf16_sp_accum2": dict(cast_bf16=True, seq_shard=True, accum=2),
    "bf16_accum4": dict(cast_bf16=True, accum=4),
    "sp_accum4": dict(seq_shard=True, accum=4),
    "sp_accum1": dict(seq_shard=True, accum=1),
    "accum4": dict(accum=4),
    "accum8": dict(accum=8),
    "sp_accum8": dict(seq_shard=True, accum=8),
    "rwkv_chunked": dict(extra=dict(rwkv_chunked=True)),
    "rwkv_chunked_sp": dict(seq_shard=True, extra=dict(rwkv_chunked=True)),
    "rwkv_chunked32": dict(extra=dict(rwkv_chunked=True, scan_chunk=32)),
    "rwkv_chunked128": dict(extra=dict(rwkv_chunked=True, scan_chunk=128)),
    "rwkv_chunked256": dict(extra=dict(rwkv_chunked=True, scan_chunk=256)),
    "chunk128": dict(extra=dict(scan_chunk=128)),
    "chunk256": dict(extra=dict(scan_chunk=256)),
    "chunk512": dict(extra=dict(scan_chunk=512)),
    "noremat": dict(extra=dict(remat=False)),
    "f32cache": dict(extra=dict(cache_f32=True)),
    "cf10": dict(extra=dict(capacity_factor=1.0)),
    "cf20": dict(extra=dict(capacity_factor=2.0)),
    "cf10_sp_accum8": dict(seq_shard=True, accum=8, extra=dict(capacity_factor=1.0)),
    "cf10_sp_accum4": dict(seq_shard=True, accum=4, extra=dict(capacity_factor=1.0)),
    "pbf16": dict(params_bf16=True),
    "pbf16_f32cache": dict(params_bf16=True, extra=dict(cache_f32=True)),
    "sp_noremat": dict(seq_shard=True, extra=dict(remat=False)),
}


def run(cell: str, variant: str, out_dir: str = "results/perf"):
    from repro.launch.dryrun import lower_cell

    arch, shape = CELLS[cell]
    v = dict(VARIANTS[variant])
    extra = v.pop("extra", None)
    rec = lower_cell(arch, shape, multi_pod=False, variant=v, extra=extra)
    rec["variant"] = variant
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{cell}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["roofline_terms_s"]
    print(
        f"{cell}/{variant}: compute={t['compute_s']:.2f}s "
        f"memory={t['memory_s']:.2f}s collective={t['collective_s']:.2f}s "
        f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
        f"bottleneck={rec['bottleneck']}",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=False)
    ap.add_argument("--variant", default="base")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for c, (a, s) in CELLS.items():
            print(c, a, s)
        return
    run(args.cell, args.variant)


if __name__ == "__main__":
    main()
