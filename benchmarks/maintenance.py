"""Maintenance-plane benchmark: stop-the-world vs amortized streaming.

Paper §6.4 observes a ~100x guest-latency hit while a chain is being
streamed: maintenance inside the serving path stalls the guest. This
scenario reproduces that cliff at fleet granularity and measures what the
``MaintenanceScheduler`` buys back. For each tenants × chain-length cell
we run a fixed number of serving *ticks* (one batched fleet resolve per
tick, the decode-step analogue) under two maintenance regimes:

* ``stw``       — stop-the-world: one tick streams and compacts EVERY
  tenant before serving (the naive background job);
* ``amortized`` — a ``MaintenanceScheduler`` streams at most K tenants
  per tick until the backlog drains.

Both end in the same steady state (all chains streamed, quanta returned
to the allocator free list); the difference is the worst-case per-tick
latency the serving path observes, reported per cell along with the
reclaimed-quanta count. Emits ``BENCH_maintenance.json``.

Run: ``PYTHONPATH=src python benchmarks/maintenance.py --tenants 32 64``
CI smoke: ``python benchmarks/maintenance.py --smoke``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json
except ModuleNotFoundError:  # invoked as `python benchmarks/maintenance.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json
from repro.core import fleet as fleet_lib
from repro.core.scheduler import MaintenanceScheduler


def build_fleet(n_tenants: int, chain_len: int, *, n_pages: int = 256,
                page_size: int = 16, writes_per_layer: int = 24,
                seed: int = 0) -> fleet_lib.ChainFleet:
    """A fleet of ``n_tenants`` chains of length ``chain_len`` with COW
    garbage: every layer overwrites a random page set, so streaming has
    superseded rows to reclaim."""
    lease_quantum = 32
    rows_per_tenant = -(-chain_len * writes_per_layer
                        // lease_quantum) * lease_quantum
    spec = fleet_lib.FleetSpec(
        n_tenants=n_tenants,
        n_pages=n_pages,
        page_size=page_size,
        max_chain=chain_len + 1,
        pool_capacity=rows_per_tenant * n_tenants,
        lease_quantum=lease_quantum,
    )
    fl = fleet_lib.create(spec)
    rng = np.random.default_rng(seed)
    for layer in range(chain_len):
        ids = np.stack([
            rng.choice(n_pages, writes_per_layer, replace=False)
            for _ in range(n_tenants)
        ]).astype(np.int32)
        data = rng.standard_normal(
            (n_tenants, writes_per_layer, page_size)).astype(np.float32)
        fl = fleet_lib.write(fl, jnp.asarray(ids), jnp.asarray(data))
        if layer < chain_len - 1:
            fl = fleet_lib.snapshot(fl)
    fleet_lib.check_pool_capacity(fl)
    return fl


def run_ticks(fl, *, ticks: int, batch: int, seed: int,
              maintain) -> tuple[list[float], fleet_lib.ChainFleet]:
    """Per-tick wall latencies of ``maintain(state, tick) ; resolve``.

    ``maintain`` mutates/returns the serving state; the resolve is the
    in-band serving op whose latency the maintenance work perturbs.
    """
    rng = np.random.default_rng(seed)
    resolver = fleet_lib.get_resolver("vanilla")
    t = fl.spec.n_tenants

    # warm the resolve jit outside the timed region (both regimes resolve
    # the same (T, B) shape, so one warmup serves every tick)
    ids = jnp.asarray(rng.integers(0, fl.spec.n_pages, (t, batch)), jnp.int32)
    jax.block_until_ready(resolver(fl, ids))

    state = fl
    lat = []
    for tick in range(ticks):
        ids = jnp.asarray(
            rng.integers(0, fl.spec.n_pages, (t, batch)), jnp.int32)
        t0 = time.perf_counter()
        state = maintain(state, tick)
        jax.block_until_ready(resolver(state, ids))
        lat.append(time.perf_counter() - t0)
    return lat, state


def bench_cell(n_tenants: int, chain_len: int, *, batch: int, ticks: int,
               k: int, seed: int = 0) -> list[dict]:
    fl = build_fleet(n_tenants, chain_len, seed=seed)
    free0 = fleet_lib.fleet_stats(fl)["quanta_free"]
    out = []

    def stw(state, tick):
        if tick == 0:   # the naive job: everything, in one serving tick
            state = fleet_lib.stream_tenants(
                state, True, np.asarray(state.length) - 2)
        return state

    def amortized(state, tick, sched_box=[None]):
        if sched_box[0] is None:
            sched_box[0] = MaintenanceScheduler(
                state, max_tenants_per_tick=k, stream_chain_threshold=2)
        sched = sched_box[0]
        sched.fleet = state
        sched.tick()    # a drained backlog ticks for (almost) free
        return sched.fleet

    for mode, maintain in (("stw", stw), ("amortized", amortized)):
        lat, end = run_ticks(fl, ticks=ticks, batch=batch, seed=seed + 1,
                             maintain=maintain)
        reclaimed = fleet_lib.fleet_stats(end)["quanta_free"] - free0
        rec = dict(
            mode=mode,
            tenants=n_tenants,
            chain=chain_len,
            k=(None if mode == "stw" else k),
            ticks=ticks,
            worst_tick_ms=max(lat) * 1e3,
            mean_tick_ms=float(np.mean(lat)) * 1e3,
            p50_tick_ms=float(np.median(lat)) * 1e3,
            quanta_reclaimed=reclaimed,
            final_mean_chain=float(np.mean(np.asarray(end.length))),
        )
        emit(
            f"maint_{mode}_t{n_tenants}_c{chain_len}",
            rec["worst_tick_ms"] * 1e3,
            f"mean_ms={rec['mean_tick_ms']:.2f};"
            f"reclaimed={reclaimed};chain={rec['final_mean_chain']:.1f}",
        )
        out.append(rec)
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tenants", type=int, nargs="+", default=[32, 64])
    p.add_argument("--chain-lengths", type=int, nargs="+", default=[8, 16])
    p.add_argument("--batch", type=int, default=128,
                   help="resolve batch per tenant per tick")
    p.add_argument("--ticks", type=int, default=48,
                   help="serving ticks per regime")
    p.add_argument("--k", type=int, default=2,
                   help="scheduler budget: tenants streamed per tick")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="BENCH_maintenance.json",
                   help="output artifact path ('' disables)")
    p.add_argument("--smoke", action="store_true",
                   help="small CI configuration (still >= 32 tenants)")
    args = p.parse_args(argv)
    if args.smoke:
        args.tenants, args.chain_lengths = [32], [6]
        args.batch, args.ticks = 64, 24

    results, ok = [], True
    for t in args.tenants:
        for c in args.chain_lengths:
            cell = bench_cell(t, c, batch=args.batch, ticks=args.ticks,
                              k=args.k, seed=args.seed)
            results.extend(cell)
            by_mode = {r["mode"]: r for r in cell}
            worst_stw = by_mode["stw"]["worst_tick_ms"]
            worst_amo = by_mode["amortized"]["worst_tick_ms"]
            if t >= 32 and not worst_amo < worst_stw:
                ok = False
                print(f"WARNING: amortized worst tick {worst_amo:.2f}ms not "
                      f"below stop-the-world {worst_stw:.2f}ms at {t} tenants")
    if args.json:
        emit_json(args.json, "maintenance", results,
                  k=args.k, batch=args.batch, ticks=args.ticks)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
