"""Shared benchmark helpers: timing, chain builders, CSV/JSON emission."""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import store


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call of a jitted fn (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def build_chain(length: int, *, scalable: bool, n_pages: int = 2048,
                page_size: int = 64, fill: float = 0.9, seed: int = 0):
    """A chain of ``length`` files with valid pages uniformly distributed
    over the layers (the paper's §6.1 methodology)."""
    ch = store.create(
        n_pages=n_pages, page_size=page_size, max_chain=length + 1,
        scalable=scalable, pool_capacity=int(n_pages * (1 + fill * 2)),
        l2_per_table=64, slice_len=16,
    )
    key = jax.random.PRNGKey(seed)
    n_filled = int(n_pages * fill)
    pages = jax.random.permutation(key, n_pages)[:n_filled]
    per_layer = max(1, n_filled // max(length, 1))
    for i in range(length):
        ids = pages[i * per_layer:(i + 1) * per_layer].astype(jnp.int32)
        if ids.shape[0] == 0:
            break
        data = jnp.full((ids.shape[0], page_size), float(i + 1))
        ch = store.write(ch, ids, data)
        if i < length - 1:
            ch = store.snapshot(ch)
    return ch


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def emit_json(path: str, benchmark: str, results: list[dict],
              **meta) -> None:
    """Write a ``BENCH_*.json`` artifact (the CI-accumulated perf trail)."""
    payload = dict(benchmark=benchmark, results=results, **meta)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(results)} records)")
