"""Migration benchmark: tenant export/import latency vs chain depth.

A provider rebalances by moving snapshot chains between hosts
(``core.migrate``): the numbers that matter are how long a tenant is
exposed to the stale-export window (export latency), how long the
destination takes to land the blob through its own lease allocator
(import latency), and what the full bit-verified round-trip costs.
For each depth the harness:

1. builds a depth-D chain per tenant (write + snapshot per layer) and
   demotes part of one tenant's frozen layers, so every measured blob
   carries both hot and cold pages;
2. times ``export_tenant`` / ``import_tenant`` (each import into a
   freshly reset slot of a different-geometry destination fleet),
   the full-disk bit-verification, and ``detach_tenant``;
3. **requires** the verification to pass — a latency number for a
   migration that corrupted data never reaches the artifact
   (``verified`` must be truthy; ``tools/check_bench.py`` enforces it).

Emits ``BENCH_migration.json``.

Run: ``PYTHONPATH=src python benchmarks/migration.py``
CI smoke: ``python benchmarks/migration.py --smoke``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json
except ModuleNotFoundError:  # invoked as `python benchmarks/migration.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json
from repro.core import fleet as fleet_lib
from repro.core import migrate
from repro.core.store import TieredStore


def _spec(n_tenants, depth, *, n_pages, page_size, quantum=16):
    rows = n_tenants * depth + 2 * quantum
    return fleet_lib.FleetSpec(
        n_tenants=n_tenants, n_pages=n_pages, page_size=page_size,
        max_chain=depth + 1,
        pool_capacity=-(-rows // quantum) * quantum,
        lease_quantum=quantum, l2_per_table=n_pages, slice_len=1,
    )


def build_fleet(spec, depth: int):
    """One write + snapshot per layer, every tenant in the batch."""
    fl = fleet_lib.create(spec)
    for layer in range(depth):
        pid = layer % spec.n_pages
        ids = jnp.full((spec.n_tenants, 1), pid, jnp.int32)
        data = jnp.full((spec.n_tenants, 1, spec.page_size),
                        float(layer + 1), jnp.float32)
        fl = fleet_lib.write(fl, ids, data)
        if layer + 1 < depth:
            fl = fleet_lib.snapshot(fl)
    if np.asarray(fl.overflow).any():
        raise RuntimeError("benchmark fleet overflowed while building")
    jax.block_until_ready(fl.l1)
    return fl


def _timed(fn, iters: int):
    """Median wall-clock ms over ``iters`` calls; returns (ms, result)."""
    times, result = [], None
    for _ in range(iters):
        t0 = time.perf_counter()
        result = fn()
        jax.block_until_ready(getattr(result, "pool", result)
                              if result is not None else 0)
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times)), result


def bench_depth(depth: int, *, n_pages: int, page_size: int,
                iters: int) -> dict:
    spec = _spec(2, depth, n_pages=n_pages, page_size=page_size)
    fl = build_fleet(spec, depth)
    store = TieredStore.for_fleet(spec)
    # tenant 0 (the migrant) carries cold layers whenever the chain has
    # frozen layers to demote — blobs measure both page classes
    fl, rep = fleet_lib.demote_tenants(fl, store, [0],
                                       max_rows=max(depth // 4, 1))
    dst_spec = _spec(3, depth, n_pages=n_pages, page_size=page_size,
                     quantum=32)
    dst = fleet_lib.create(dst_spec, scalable=False)
    dst_store = TieredStore.for_fleet(dst_spec)

    export_ms, blob = _timed(lambda: migrate.export_tenant(fl, 0,
                                                           store=store),
                             iters)

    def _import():
        # import resets slot 0 each call: every iteration lands in a
        # freshly evicted slot, like repeated rebalances into one host
        s = TieredStore.for_fleet(dst_spec) if blob.n_cold else dst_store
        return migrate.import_tenant(dst, 0, blob, store=s)

    import_ms, _ = _timed(_import, iters)

    want = migrate.materialize_tenant(fl, 0, store=store)
    # full round-trip through the orchestrator: export + import + verify
    # + detach, bit-checked internally (raises on mismatch)
    t0 = time.perf_counter()
    src_after, dst_after, report = migrate.migrate_tenant(
        fl, 0, dst, 1, src_store=store, dst_store=dst_store)
    roundtrip_ms = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    got = migrate.materialize_tenant(dst_after, 1, store=dst_store)
    verify_ms = (time.perf_counter() - t0) * 1e3
    if not np.array_equal(want.view(np.uint8), got.view(np.uint8)):
        raise AssertionError(f"depth {depth}: migrated bytes differ")
    verified = report["verified"]

    blob2 = migrate.export_tenant(src_after, 1)
    t0 = time.perf_counter()
    migrate.detach_tenant(src_after, 1, blob2)
    detach_ms = (time.perf_counter() - t0) * 1e3

    rec = dict(
        depth=depth, n_pages=n_pages, page_size=page_size,
        rows_hot=blob.n_hot, rows_cold=blob.n_cold,
        blob_kb=blob.nbytes() / 1024,
        export_ms=export_ms, import_ms=import_ms, verify_ms=verify_ms,
        detach_ms=detach_ms, roundtrip_ms=roundtrip_ms,
        verified=bool(verified),
    )
    emit(f"migrate_d{depth}", roundtrip_ms * 1e3,
         f"hot={blob.n_hot};cold={blob.n_cold};"
         f"export_ms={export_ms:.2f};import_ms={import_ms:.2f}")
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--depths", type=int, nargs="+", default=[1, 64, 500])
    p.add_argument("--pages", type=int, default=64)
    p.add_argument("--page-size", type=int, default=32)
    p.add_argument("--iters", type=int, default=5)
    p.add_argument("--json", default="BENCH_migration.json",
                   help="output artifact path ('' disables)")
    p.add_argument("--smoke", action="store_true",
                   help="small CI configuration (depth 500 stays in — "
                        "the deep-chain latency is the point)")
    args = p.parse_args(argv)
    if args.smoke:
        args.page_size, args.iters = 8, 3

    results = [
        bench_depth(d, n_pages=args.pages, page_size=args.page_size,
                    iters=args.iters)
        for d in args.depths
    ]
    if args.json:
        emit_json(args.json, "migration", results, iters=args.iters)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
