"""One benchmark per paper table/figure (see DESIGN.md §7 index).

Scales are reduced vs the paper's 20-50 GB disks (pages stand in for 64 KB
clusters) but every *shape* claim is measured, not modelled, except where
the paper itself models (Eq. 2). Output: ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_chain, emit, time_fn
from repro.core import cache, metrics, store
from repro.core.cache import cache_memory_bytes
from repro.checkpoint.snapstore_ckpt import SnapshotCheckpointer

CHAIN_LENGTHS = (1, 4, 16, 64, 128)


def fig10_assessment():
    """Vanilla-only: throughput + memory degradation with chain size."""
    base = None
    for n in CHAIN_LENGTHS:
        ch = build_chain(n, scalable=False)
        dt = time_fn(lambda c=ch: store.materialize(c, method="vanilla"))
        mb = ch.spec.n_pages * ch.spec.page_size * 4 / 2**20
        thr = mb / dt
        base = base or thr
        mem = cache_memory_bytes(ch.spec, 64, n, unified=False)
        emit(f"fig10_vanilla_chain{n}", dt * 1e6,
             f"read_MBps={thr:.0f};rel_thr={thr/base:.2f};cache_bytes={mem}")


def fig12_memory():
    spec = build_chain(1, scalable=True).spec
    for n in (1, 5, 50, 100, 500, 1000):
        v = cache_memory_bytes(spec, 64, n, unified=False)
        u = cache_memory_bytes(spec, 64, n, unified=True)
        emit(f"fig12_chain{n}", 0.0,
             f"vanilla_bytes={v};unified_bytes={u};reduction={v/u:.1f}x")


def fig13_lowlevel():
    reqs = jnp.arange(1024, dtype=jnp.int32)
    for n in (1, 16, 48):
        chv = build_chain(n, scalable=False, n_pages=1024)
        chs = build_chain(n, scalable=True, n_pages=1024)
        tv = cache.summarize(cache.simulate_vanilla(chv, reqs, 16))
        tu = cache.summarize(cache.simulate_unified(chs, reqs, 16))
        emit(f"fig13_chain{n}", 0.0,
             f"v_miss={tv['misses']};v_unal={tv['hit_unallocated']};"
             f"v_probes={tv['probes']};u_miss={tu['misses']};"
             f"u_unal={tu['hit_unallocated']};u_probes={tu['probes']}")


def fig14_latency():
    reqs = jnp.arange(1024, dtype=jnp.int32)
    for n in (1, 64):
        chv = build_chain(n, scalable=False, n_pages=1024)
        chs = build_chain(n, scalable=True, n_pages=1024)
        lv = np.asarray(metrics.trace_latencies(
            cache.simulate_vanilla(chv, reqs, 16)))
        lu = np.asarray(metrics.trace_latencies(
            cache.simulate_unified(chs, reqs, 16)))
        emit(f"fig14_chain{n}", float(np.mean(lv)) * 1e6,
             f"v_mean_us={np.mean(lv)*1e6:.1f};v_p99_us={np.percentile(lv,99)*1e6:.1f};"
             f"u_mean_us={np.mean(lu)*1e6:.1f};u_p99_us={np.percentile(lu,99)*1e6:.1f}")


def fig15_dd():
    """Sequential full-disk read (the dd benchmark), vanilla vs scalable."""
    base_v = base_s = None
    for n in CHAIN_LENGTHS:
        chv = build_chain(n, scalable=False)
        chs = build_chain(n, scalable=True)
        mb = chv.spec.n_pages * chv.spec.page_size * 4 / 2**20
        tv = time_fn(lambda c=chv: store.materialize(c, method="vanilla"))
        ts = time_fn(lambda c=chs: store.materialize(c, method="direct"))
        thr_v, thr_s = mb / tv, mb / ts
        base_v = base_v or thr_v
        base_s = base_s or thr_s
        emit(f"fig15_chain{n}", tv * 1e6,
             f"vanilla_MBps={thr_v:.0f};scalable_MBps={thr_s:.0f};"
             f"v_rel={thr_v/base_v:.2f};s_rel={thr_s/base_s:.2f}")


def fig16_cachesize():
    """Random 4K-read throughput vs cache size (fio analogue).

    Modelled throughput from the simulator's event stream: the unified
    cache gets S slots; the vanilla per-file caches get S/L each (the
    paper's equal-total-memory protocol)."""
    n = 32
    chv = build_chain(n, scalable=False, n_pages=1024)
    chs = build_chain(n, scalable=True, n_pages=1024)
    key = jax.random.PRNGKey(7)
    reqs = jax.random.randint(key, (2048,), 0, 1024, dtype=jnp.int32)
    for slots in (4, 16, 64, 256):
        per_file = max(1, slots // n)
        tv = cache.simulate_vanilla(chv, reqs, per_file)
        tu = cache.simulate_unified(chs, reqs, slots)
        lv = float(jnp.sum(metrics.trace_latencies(tv)))
        lu = float(jnp.sum(metrics.trace_latencies(tu)))
        emit(f"fig16_slots{slots}", 0.0,
             f"vanilla_iops={2048/lv:.0f};unified_iops={2048/lu:.0f};"
             f"speedup={lv/lu:.1f}x")


def fig17_boot():
    """VM boot → cold checkpoint-restore from a delta chain.

    Saves are *incremental* (a fine-tune-style run touching a slice of the
    weights per checkpoint), so each snapshot holds a small delta — the
    paper's workload shape."""
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    for n in (1, 8, 32):
        state = dict(w=w, step=jnp.zeros((), jnp.int32))
        cks = SnapshotCheckpointer(state, page_size=512, max_chain=n + 2,
                                   stream_threshold=10**9)
        ckv = SnapshotCheckpointer(state, page_size=512, max_chain=n + 2,
                                   scalable=False, stream_threshold=10**9)
        for i in range(n):
            state = dict(
                w=state["w"].at[(7 * i) % 256].add(1.0),  # sparse delta
                step=jnp.asarray(i, jnp.int32),
            )
            cks.save(state)
            ckv.save(state)
        td = time_fn(lambda: cks.restore(method="direct"), iters=3)
        tv = time_fn(lambda: ckv.restore(method="vanilla"), iters=3)
        emit(f"fig17_chain{n}", tv * 1e6,
             f"vanilla_restore_ms={tv*1e3:.1f};direct_restore_ms={td*1e3:.1f};"
             f"v_lookups={ckv.resolve_cost('vanilla')};"
             f"d_lookups={cks.resolve_cost('direct')}")


def fig18_ycsb():
    """YCSB-C (uniform read-only) over a 25%-populated store."""
    key = jax.random.PRNGKey(3)
    n_reqs = 4096
    for n in (16, 48):
        chv = build_chain(n, scalable=False, fill=0.25)
        chs = build_chain(n, scalable=True, fill=0.25)
        reqs = jax.random.randint(key, (n_reqs,), 0, chv.spec.n_pages,
                                  dtype=jnp.int32)
        read_v = jax.jit(lambda c, r: store.read(c, r, method="vanilla")[0])
        read_s = jax.jit(lambda c, r: store.read(c, r, method="direct")[0])
        tv = time_fn(read_v, chv, reqs)
        ts = time_fn(read_s, chs, reqs)
        emit(f"fig18_chain{n}", tv * 1e6,
             f"vanilla_kops={n_reqs/tv/1e3:.0f};scalable_kops={n_reqs/ts/1e3:.0f};"
             f"improvement={(tv/ts-1)*100:.0f}%")


def fig19_snapshot():
    """Snapshot creation cost + Eq. 2 disk overhead.

    Wall time in our dense-array store is dominated by the functional
    buffer copy for both formats, so the *metadata written per snapshot*
    (what the paper's Fig 19 measures as time and disk) is reported from
    the format model: vanilla writes header+L1 only; scalable copies the
    full L2 set forward (Eq. 2)."""
    from repro.core import chain as chain_lib

    for n_pages in (1024, 4096):
        chv = build_chain(4, scalable=False, n_pages=n_pages)
        chs = build_chain(4, scalable=True, n_pages=n_pages)
        tv = time_fn(lambda c=chv: store.snapshot(c), iters=3)
        ts = time_fn(lambda c=chs: store.snapshot(c), iters=3)
        cost = chain_lib.snapshot_cost_model(chs.spec)
        eq2 = metrics.eq2_snapshot_overhead_bytes(
            n_pages * chs.spec.page_size * 4, chs.spec.page_size * 4, 8, 0)
        emit(f"fig19_pages{n_pages}", ts * 1e6,
             f"vanilla_meta_bytes={cost['vanilla_bytes']};"
             f"scalable_meta_bytes={cost['scalable_bytes']};"
             f"meta_ratio={cost['scalable_bytes']/cost['vanilla_bytes']:.0f}x;"
             f"vanilla_us={tv*1e6:.0f};scalable_us={ts*1e6:.0f};"
             f"eq2_overhead_bytes={eq2}")


ALL = [fig10_assessment, fig12_memory, fig13_lowlevel, fig14_latency,
       fig15_dd, fig16_cachesize, fig17_boot, fig18_ycsb, fig19_snapshot]
