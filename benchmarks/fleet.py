"""Multi-tenant fleet benchmark: batched resolve/COW vs a per-disk loop,
plus a resolver-method axis (vmapped jnp gather vs Pallas kernels).

The paper's Eq. 1 scaling is measured per chain; the cloud trace in §3 is
thousands of tenant disks hitting one backend concurrently. Two sections
(each a ``section`` key in the JSON rows):

``fleet_vs_loop`` sweeps tenants × chain-length and times, per cell:

* ``fleet``  — one batched ``core.fleet`` resolve over all T tenants
  (single dispatch, stacked tables, shared pool);
* ``loop``   — the same resolution as a python loop over T single-chain
  ``core.resolve`` calls (one dispatch + transfer per tenant — how a
  per-disk driver fleet behaves);

verifying bit-identical owner/found metadata between the two, plus the
fleet-granularity Eq. 1 signal (vanilla lookups grow with chain length,
direct stays at one per request).

``resolver`` sweeps resolver methods (``vanilla`` vs ``pallas_vanilla``,
``direct`` vs ``pallas_direct``) over chain lengths up to 500 — the
paper's RocksDB experiment regime — on fleets whose stacked tables are
*synthesized* directly (no op replay, so a 500-layer chain builds in
milliseconds; see ``synth_fleet``). Each kernel cell is verified
bit-identical against its vmapped-gather counterpart on the same fleet.

Run: ``PYTHONPATH=src python benchmarks/fleet.py --tenants 64``
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json, time_fn
except ModuleNotFoundError:  # invoked as `python benchmarks/fleet.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json, time_fn
from repro.core import fleet as fleet_lib
from repro.core import format as fmt
from repro.core import resolve as resolve_lib
from repro.core import store


def build_fleet(n_tenants: int, chain_len: int, *, n_pages: int = 512,
                page_size: int = 16, writes_per_layer: int = 32,
                seed: int = 0):
    """A fleet of ``n_tenants`` chains of length ``chain_len`` plus the
    equivalent list of independent single chains (same logical content)."""
    lease_quantum = 64
    # each tenant's rows round up to whole lease quanta (fragmentation)
    spec = fleet_lib.FleetSpec(
        n_tenants=n_tenants,
        n_pages=n_pages,
        page_size=page_size,
        max_chain=chain_len + 1,
        pool_capacity=_round_up(chain_len * writes_per_layer,
                                lease_quantum) * n_tenants,
        lease_quantum=lease_quantum,
    )
    fl = fleet_lib.create(spec)
    chains = [
        store.create(n_pages=n_pages, page_size=page_size,
                     max_chain=chain_len + 1,
                     pool_capacity=chain_len * writes_per_layer + 64)
        for _ in range(n_tenants)
    ]
    rng = np.random.default_rng(seed)
    for layer in range(chain_len):
        ids = np.stack([
            rng.choice(n_pages, writes_per_layer, replace=False)
            for _ in range(n_tenants)
        ]).astype(np.int32)
        data = rng.standard_normal(
            (n_tenants, writes_per_layer, page_size)).astype(np.float32)
        fl = fleet_lib.write(fl, jnp.asarray(ids), jnp.asarray(data))
        for t in range(n_tenants):
            chains[t] = store.write(chains[t], jnp.asarray(ids[t]),
                                    jnp.asarray(data[t]))
        if layer < chain_len - 1:
            fl = fleet_lib.snapshot(fl)
            chains = [store.snapshot(c) for c in chains]
    fleet_lib.check_pool_capacity(fl)
    return fl, chains


def _round_up(n: int, q: int) -> int:
    return -(-n // q) * q


def synth_fleet(n_tenants: int, chain_len: int, *, n_pages: int = 512,
                page_size: int = 16, fill: float = 0.9,
                scalable: bool = True, seed: int = 0):
    """Synthesize a resolve-ready fleet of ``chain_len``-layer chains.

    Stacked L1/L2 tables are constructed directly with numpy instead of
    replaying ``chain_len`` write+snapshot rounds, so the paper's 500-layer
    RocksDB regime builds in milliseconds. Per tenant, ``fill * n_pages``
    pages are live with owners uniformly distributed over the layers (the
    paper's §6.1 methodology):

    * ``scalable=True`` mirrors copy-forward snapshots: layer ``l`` carries
      an entry for every page owned by layers ``<= l``, bfi-stamped — the
      direct path is O(1) and the walk stops at the active layer;
    * ``scalable=False`` is a vanilla chain: layer ``l`` only holds its
      own writes, so the walk pays the full Eq. 1 depth.

    The result is resolve/read-path only: the lease allocator state is
    left empty (do not ``fleet.write`` to it).
    """
    rng = np.random.default_rng(seed)
    n_filled = int(n_pages * fill)
    lease_quantum = 64
    spec = fleet_lib.FleetSpec(
        n_tenants=n_tenants,
        n_pages=n_pages,
        page_size=page_size,
        max_chain=chain_len,
        pool_capacity=_round_up(n_filled * n_tenants, lease_quantum),
        lease_quantum=lease_quantum,
    )
    owner = np.full((n_tenants, n_pages), -1, np.int64)       # owning layer
    rows = np.zeros((n_tenants, n_pages), np.uint32)          # pool row
    for t in range(n_tenants):
        pages = rng.permutation(n_pages)[:n_filled]
        owner[t, pages] = rng.integers(0, chain_len, n_filled)
        rows[t, pages] = t * n_filled + np.arange(n_filled, dtype=np.uint32)

    layers = np.arange(chain_len, dtype=np.int64)[None, :, None]  # (1, C, 1)
    has_page = owner[:, None, :] >= 0
    if scalable:
        alloc = has_page & (owner[:, None, :] <= layers)
    else:
        alloc = owner[:, None, :] == layers
    entries = fmt.pack_entry(
        jnp.asarray(np.broadcast_to(rows[:, None, :], alloc.shape)),
        jnp.asarray(np.maximum(owner, 0)[:, None, :] * np.ones_like(layers)),
        allocated=jnp.asarray(alloc),
        bfi_valid=scalable,
    )
    l2 = fmt.empty_entries((n_tenants, spec.max_chain, n_pages))
    l2 = l2.at[:, :chain_len].set(entries)
    l1 = jnp.asarray(
        alloc.reshape(n_tenants, chain_len, spec.n_l1, spec.l2_per_table)
        .max(axis=3).astype(np.uint32)
    )
    pool = jnp.asarray(
        rng.standard_normal((spec.pool_capacity, page_size)), jnp.float32)

    fl = fleet_lib.create(spec, scalable=scalable)
    return dataclasses.replace(
        fl,
        l1=fl.l1.at[:, :chain_len].set(l1),
        l2=l2,
        pool=pool,
        length=jnp.full((n_tenants,), chain_len, jnp.int32),
        alloc_count=jnp.full((n_tenants,), n_filled, jnp.int32),
    )


#: kernel method → the vmapped-jnp method producing bit-identical results
KERNEL_BASELINE = {"pallas_vanilla": "vanilla", "pallas_direct": "direct"}


def bench_resolver_cell(n_tenants: int, chain_len: int, method: str, *,
                        batch: int, seed: int = 0, verify: bool = True,
                        iters: int = 9) -> dict:
    """Time one resolver method on a synthesized fleet.

    Walk methods run on vanilla-format chains (the regime where the walk
    actually pays O(chain)); direct methods on scalable chains (bfi
    entries exist to be looked up). Kernel methods are verified
    bit-identical — all five ResolveResult fields, including ptr — to
    their vmapped baseline on the same fleet.
    """
    scalable = method in ("direct", "pallas_direct")
    fl = synth_fleet(n_tenants, chain_len, scalable=scalable, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ids = jnp.asarray(
        rng.integers(0, fl.spec.n_pages, (n_tenants, batch)), jnp.int32)

    resolver = fleet_lib.get_resolver(method)
    if verify and method in KERNEL_BASELINE:
        base = fleet_lib.get_resolver(KERNEL_BASELINE[method])(fl, ids)
        res = resolver(fl, ids)
        for field in res._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res, field)),
                np.asarray(getattr(base, field)),
                err_msg=f"{method} vs {KERNEL_BASELINE[method]} "
                        f"field {field} (chain {chain_len})",
            )

    t_res = time_fn(resolver, fl, ids, warmup=2, iters=iters)
    res = resolver(fl, ids)
    pages = n_tenants * batch
    return dict(
        section="resolver",
        tenants=n_tenants,
        chain=chain_len,
        method=method,
        format="scalable" if scalable else "vanilla",
        resolve_us=t_res * 1e6,
        mpages_s=pages / t_res / 1e6,
        mean_lookups=float(jnp.mean(res.lookups)),
    )


def verify_equivalence(fl, chains, ids, method: str) -> None:
    """Batched fleet resolution must match the per-chain loop exactly."""
    fr = fleet_lib.get_resolver(method)(fl, ids)
    single = resolve_lib.get_resolver(method)
    for t, ch in enumerate(chains):
        cr = single(ch, ids[t])
        for field in ("owner", "found", "zero", "lookups"):
            np.testing.assert_array_equal(
                np.asarray(getattr(fr, field)[t]),
                np.asarray(getattr(cr, field)),
                err_msg=f"{method} tenant {t} field {field}",
            )
    # data equality (ptr spaces differ: shared pool vs per-chain pools)
    fleet_data, _ = fleet_lib.read(fl, ids, method=method)
    for t, ch in enumerate(chains):
        got, _ = store.read(ch, ids[t], method=method)
        np.testing.assert_allclose(np.asarray(fleet_data[t]), np.asarray(got),
                                   rtol=1e-6, err_msg=f"{method} tenant {t}")


def bench_cell(n_tenants: int, chain_len: int, *, batch: int, method: str,
               seed: int = 0, verify: bool = True, iters: int = 9) -> dict:
    fl, chains = build_fleet(n_tenants, chain_len, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ids = jnp.asarray(
        rng.integers(0, fl.spec.n_pages, (n_tenants, batch)), jnp.int32)
    if verify:
        verify_equivalence(fl, chains, ids, method)

    fleet_resolver = fleet_lib.get_resolver(method)
    single = resolve_lib.get_resolver(method)

    def run_fleet(ids):
        return fleet_resolver(fl, ids)

    def run_loop(ids):
        return [single(ch, ids[t]) for t, ch in enumerate(chains)]

    t_fleet = time_fn(run_fleet, ids, warmup=2, iters=iters)
    t_loop = time_fn(run_loop, ids, warmup=2, iters=iters)
    pages = n_tenants * batch
    res = fleet_resolver(fl, ids)
    return dict(
        section="fleet_vs_loop",
        tenants=n_tenants,
        chain=chain_len,
        method=method,
        fleet_us=t_fleet * 1e6,
        loop_us=t_loop * 1e6,
        speedup=t_loop / t_fleet,
        fleet_mpages_s=pages / t_fleet / 1e6,
        mean_lookups=float(jnp.mean(res.lookups)),
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tenants", type=int, nargs="+", default=[64])
    p.add_argument("--chain-lengths", type=int, nargs="+", default=[4, 16])
    p.add_argument("--methods", nargs="+",
                   default=["vanilla", "direct"],
                   choices=["vanilla", "direct", "auto"])
    p.add_argument("--batch", type=int, default=256,
                   help="resolve batch per tenant per call")
    p.add_argument("--resolver-tenants", type=int, nargs="+", default=[8],
                   help="tenant counts for the resolver-method sweep")
    p.add_argument("--resolver-chain-lengths", type=int, nargs="+",
                   default=[4, 64, 500],
                   help="chain lengths for the resolver-method sweep "
                        "(500 = the paper's RocksDB regime)")
    p.add_argument("--resolver-methods", nargs="+",
                   default=["vanilla", "pallas_vanilla",
                            "direct", "pallas_direct"],
                   choices=["vanilla", "pallas_vanilla",
                            "direct", "pallas_direct"])
    p.add_argument("--no-resolver-sweep", action="store_true",
                   help="skip the resolver-method section")
    p.add_argument("--no-verify", action="store_true")
    p.add_argument("--iters", type=int, default=9,
                   help="timing iterations per cell (median reported)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="",
                   help="also write a BENCH_fleet.json artifact here")
    args = p.parse_args(argv)

    ok = True
    results = []
    for method in args.methods:
        for t in args.tenants:
            for c in args.chain_lengths:
                r = bench_cell(t, c, batch=args.batch, method=method,
                               seed=args.seed, verify=not args.no_verify,
                               iters=args.iters)
                results.append(r)
                emit(
                    f"fleet_{method}_t{t}_c{c}", r["fleet_us"],
                    f"loop_us={r['loop_us']:.0f};speedup={r['speedup']:.1f}x;"
                    f"fleet_mpages_s={r['fleet_mpages_s']:.2f};"
                    f"mean_lookups={r['mean_lookups']:.1f}",
                )
                if t >= 64 and r["speedup"] < 5.0:
                    ok = False
                    print(f"WARNING: speedup {r['speedup']:.1f}x < 5x "
                          f"at {t} tenants ({method}, chain {c})")
    if not args.no_resolver_sweep:
        for method in args.resolver_methods:
            for t in args.resolver_tenants:
                for c in args.resolver_chain_lengths:
                    r = bench_resolver_cell(
                        t, c, method, batch=args.batch, seed=args.seed,
                        verify=not args.no_verify, iters=args.iters)
                    results.append(r)
                    emit(
                        f"resolver_{method}_t{t}_c{c}", r["resolve_us"],
                        f"format={r['format']};"
                        f"mpages_s={r['mpages_s']:.2f};"
                        f"mean_lookups={r['mean_lookups']:.1f}",
                    )
    if args.json:
        emit_json(args.json, "fleet", results, batch=args.batch)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
