"""Multi-tenant fleet benchmark: batched resolve/COW vs a per-disk loop.

The paper's Eq. 1 scaling is measured per chain; the cloud trace in §3 is
thousands of tenant disks hitting one backend concurrently. This scenario
sweeps tenants × chain-length and times, for each cell:

* ``fleet``  — one batched ``core.fleet`` resolve over all T tenants
  (single dispatch, stacked tables, shared pool);
* ``loop``   — the same resolution as a python loop over T single-chain
  ``core.resolve`` calls (one dispatch + transfer per tenant — how a
  per-disk driver fleet behaves);

verifying bit-identical owner/found metadata between the two, plus the
fleet-granularity Eq. 1 signal (vanilla lookups grow with chain length,
direct stays at one per request).

Run: ``PYTHONPATH=src python benchmarks/fleet.py --tenants 64``
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, emit_json, time_fn
except ModuleNotFoundError:  # invoked as `python benchmarks/fleet.py`
    import pathlib
    import sys

    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))  # repro without pip install -e
    from benchmarks.common import emit, emit_json, time_fn
from repro.core import fleet as fleet_lib
from repro.core import resolve as resolve_lib
from repro.core import store


def build_fleet(n_tenants: int, chain_len: int, *, n_pages: int = 512,
                page_size: int = 16, writes_per_layer: int = 32,
                seed: int = 0):
    """A fleet of ``n_tenants`` chains of length ``chain_len`` plus the
    equivalent list of independent single chains (same logical content)."""
    lease_quantum = 64
    # each tenant's rows round up to whole lease quanta (fragmentation)
    spec = fleet_lib.FleetSpec(
        n_tenants=n_tenants,
        n_pages=n_pages,
        page_size=page_size,
        max_chain=chain_len + 1,
        pool_capacity=_round_up(chain_len * writes_per_layer,
                                lease_quantum) * n_tenants,
        lease_quantum=lease_quantum,
    )
    fl = fleet_lib.create(spec)
    chains = [
        store.create(n_pages=n_pages, page_size=page_size,
                     max_chain=chain_len + 1,
                     pool_capacity=chain_len * writes_per_layer + 64)
        for _ in range(n_tenants)
    ]
    rng = np.random.default_rng(seed)
    for layer in range(chain_len):
        ids = np.stack([
            rng.choice(n_pages, writes_per_layer, replace=False)
            for _ in range(n_tenants)
        ]).astype(np.int32)
        data = rng.standard_normal(
            (n_tenants, writes_per_layer, page_size)).astype(np.float32)
        fl = fleet_lib.write(fl, jnp.asarray(ids), jnp.asarray(data))
        for t in range(n_tenants):
            chains[t] = store.write(chains[t], jnp.asarray(ids[t]),
                                    jnp.asarray(data[t]))
        if layer < chain_len - 1:
            fl = fleet_lib.snapshot(fl)
            chains = [store.snapshot(c) for c in chains]
    fleet_lib.check_pool_capacity(fl)
    return fl, chains


def _round_up(n: int, q: int) -> int:
    return -(-n // q) * q


def verify_equivalence(fl, chains, ids, method: str) -> None:
    """Batched fleet resolution must match the per-chain loop exactly."""
    fr = fleet_lib.get_resolver(method)(fl, ids)
    single = resolve_lib.get_resolver(method)
    for t, ch in enumerate(chains):
        cr = single(ch, ids[t])
        for field in ("owner", "found", "zero", "lookups"):
            np.testing.assert_array_equal(
                np.asarray(getattr(fr, field)[t]),
                np.asarray(getattr(cr, field)),
                err_msg=f"{method} tenant {t} field {field}",
            )
    # data equality (ptr spaces differ: shared pool vs per-chain pools)
    fleet_data, _ = fleet_lib.read(fl, ids, method=method)
    for t, ch in enumerate(chains):
        got, _ = store.read(ch, ids[t], method=method)
        np.testing.assert_allclose(np.asarray(fleet_data[t]), np.asarray(got),
                                   rtol=1e-6, err_msg=f"{method} tenant {t}")


def bench_cell(n_tenants: int, chain_len: int, *, batch: int, method: str,
               seed: int = 0, verify: bool = True, iters: int = 9) -> dict:
    fl, chains = build_fleet(n_tenants, chain_len, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ids = jnp.asarray(
        rng.integers(0, fl.spec.n_pages, (n_tenants, batch)), jnp.int32)
    if verify:
        verify_equivalence(fl, chains, ids, method)

    fleet_resolver = fleet_lib.get_resolver(method)
    single = resolve_lib.get_resolver(method)

    def run_fleet(ids):
        return fleet_resolver(fl, ids)

    def run_loop(ids):
        return [single(ch, ids[t]) for t, ch in enumerate(chains)]

    t_fleet = time_fn(run_fleet, ids, warmup=2, iters=iters)
    t_loop = time_fn(run_loop, ids, warmup=2, iters=iters)
    pages = n_tenants * batch
    res = fleet_resolver(fl, ids)
    return dict(
        tenants=n_tenants,
        chain=chain_len,
        method=method,
        fleet_us=t_fleet * 1e6,
        loop_us=t_loop * 1e6,
        speedup=t_loop / t_fleet,
        fleet_mpages_s=pages / t_fleet / 1e6,
        mean_lookups=float(jnp.mean(res.lookups)),
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tenants", type=int, nargs="+", default=[64])
    p.add_argument("--chain-lengths", type=int, nargs="+", default=[4, 16])
    p.add_argument("--methods", nargs="+",
                   default=["vanilla", "direct"],
                   choices=["vanilla", "direct", "auto"])
    p.add_argument("--batch", type=int, default=256,
                   help="resolve batch per tenant per call")
    p.add_argument("--no-verify", action="store_true")
    p.add_argument("--iters", type=int, default=9,
                   help="timing iterations per cell (median reported)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="",
                   help="also write a BENCH_fleet.json artifact here")
    args = p.parse_args(argv)

    ok = True
    results = []
    for method in args.methods:
        for t in args.tenants:
            for c in args.chain_lengths:
                r = bench_cell(t, c, batch=args.batch, method=method,
                               seed=args.seed, verify=not args.no_verify,
                               iters=args.iters)
                results.append(r)
                emit(
                    f"fleet_{method}_t{t}_c{c}", r["fleet_us"],
                    f"loop_us={r['loop_us']:.0f};speedup={r['speedup']:.1f}x;"
                    f"fleet_mpages_s={r['fleet_mpages_s']:.2f};"
                    f"mean_lookups={r['mean_lookups']:.1f}",
                )
                if t >= 64 and r["speedup"] < 5.0:
                    ok = False
                    print(f"WARNING: speedup {r['speedup']:.1f}x < 5x "
                          f"at {t} tenants ({method}, chain {c})")
    if args.json:
        emit_json(args.json, "fleet", results, batch=args.batch)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
