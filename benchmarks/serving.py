"""Beyond-paper benchmarks: the serving-side integration.

* fork-chain resolution cost (vanilla parent-walk vs direct flattening) —
  the paper's chain-length scaling measured on KV block tables;
* COW memory sharing across forks (blocks-in-use vs independent copies);
* paged decode attention throughput via the kernel ref path.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.paged_attention import ops as pa_ops
from repro.kvcache.paged import PagedKVCache, PagedKVConfig


def fork_resolution():
    cfg = PagedKVConfig(n_layers=4, n_kv_heads=4, head_dim=32, block_size=8,
                        n_blocks=4096, max_blocks_per_seq=32)
    for depth in (1, 8, 32, 64):
        out = {}
        for scalable in (False, True):
            kv = PagedKVCache(cfg, scalable=scalable)
            sid = kv.new_seq()
            k = jnp.zeros((4, 16, 4, 32))
            kv.append_prefill(sid, k, k)
            for _ in range(depth):
                sid = kv.fork(sid)
            kv.block_table(sid)        # warm the stacked-resolve jit
            kv.lookup_count = 0
            t0 = time.perf_counter()
            kv.block_table(sid)
            dt = time.perf_counter() - t0
            out[scalable] = (kv.lookup_count, dt)
        emit(f"serve_fork_depth{depth}", out[False][1] * 1e6,
             f"vanilla_lookups={out[False][0]};direct_lookups={out[True][0]};"
             f"vanilla_us={out[False][1]*1e6:.0f};direct_us={out[True][1]*1e6:.0f}")


def cow_sharing():
    cfg = PagedKVConfig(n_layers=4, n_kv_heads=4, head_dim=32, block_size=8,
                        n_blocks=4096, max_blocks_per_seq=64)
    kv = PagedKVCache(cfg, scalable=True)
    root = kv.new_seq()
    k = jnp.zeros((4, 256, 4, 32))  # 32 blocks of shared prefix
    kv.append_prefill(root, k, k)
    for n_forks in (1, 4, 16):
        kv2 = PagedKVCache(cfg, scalable=True)
        r = kv2.new_seq()
        kv2.append_prefill(r, k, k)
        for _ in range(n_forks):
            c = kv2.fork(r)
            kv2.append(c, k[:, 0], k[:, 0])  # one divergent token each
        used = kv2.blocks_in_use()
        independent = 32 * (n_forks + 1)
        emit(f"serve_cow_forks{n_forks}", 0.0,
             f"blocks_used={used};independent_copy_blocks={independent};"
             f"saving={independent/used:.1f}x")


def paged_decode_throughput():
    b, h, hkv, d, bs, nb, m = 8, 16, 4, 64, 16, 512, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, d), jnp.float32)
    pk = jax.random.normal(key, (nb, bs, hkv, d), jnp.float32)
    pv = jax.random.normal(key, (nb, bs, hkv, d), jnp.float32)
    tables = jax.random.randint(key, (b, m), 0, nb, dtype=jnp.int32)
    lengths = jnp.full((b,), bs * m, jnp.int32)
    fn = jax.jit(pa_ops.paged_attention)
    dt = time_fn(fn, q, pk, pv, tables, lengths)
    flops = 4.0 * b * h * d * bs * m
    emit("serve_paged_attn", dt * 1e6,
         f"tokens={bs*m};gflops={flops/dt/1e9:.1f}")


def gradient_compression():
    """int8 + error-feedback DP all-reduce: wire bytes and convergence."""
    import numpy as np

    from repro.distributed import compression as comp

    rng = np.random.default_rng(0)
    tree = dict(w=jnp.asarray(rng.standard_normal((256, 64)), jnp.float32),
                b=jnp.asarray(rng.standard_normal(64), jnp.float32))
    full = comp.wire_bytes(tree, compressed=False)
    small = comp.wire_bytes(tree, compressed=True)
    # error-feedback drift over repeated steps
    err = comp.init_error_state(tree)
    acc = jax.tree.map(jnp.zeros_like, tree)
    n = 32
    for _ in range(n):
        for kk in tree:
            q, s = comp.quantize_int8(tree[kk] + err[kk])
            deq = q.astype(jnp.float32) * s
            err[kk] = tree[kk] + err[kk] - deq
            acc[kk] = acc[kk] + deq
    drift = max(
        float(jnp.max(jnp.abs(acc[kk] / n - tree[kk]))) for kk in tree
    )
    emit("serve_grad_compression", 0.0,
         f"wire_bytes_f32={full};wire_bytes_int8={small};"
         f"saving={full/small:.1f}x;ef_drift_after_{n}_steps={drift:.2e}")


ALL = [fork_resolution, cow_sharing, paged_decode_throughput,
       gradient_compression]
