#!/usr/bin/env python
"""fleetlint — AST-level invariant checks for the fleet's contracts.

Usage:
    python tools/fleetlint.py [PATH ...]      # default: src
    python tools/fleetlint.py --list-rules

Exit status is non-zero iff any non-waived finding remains. Waive a
finding with an inline ``# fleetlint: disable=FL00x`` comment on (or
directly above) the offending line — plus a justification, per the
waiver policy in docs/invariants.md.

The rule engine lives in ``src/repro/analysis/`` and is stdlib-only,
so this runs in CI's lint job without installing jax.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import RULES, render, run_lint  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, title in sorted(RULES.items()):
            print(f"{code}  {title}")
        return 0

    findings = []
    for p in args.paths:
        root = Path(p)
        if not root.is_absolute():
            root = REPO / root
        if not root.is_dir():
            print(f"fleetlint: not a directory: {p}", file=sys.stderr)
            return 2
        findings.extend(run_lint(root))

    if findings:
        print(render(findings))
        print(f"\nfleetlint: {len(findings)} finding(s)")
        return 1
    print("fleetlint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
