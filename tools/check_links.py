#!/usr/bin/env python3
"""Verify that relative markdown links in the repo resolve to real targets.

Scans every tracked-tree ``*.md`` (skipping hidden and cache dirs) for
inline links/images ``[text](target)``, resolves each relative target
against the containing file's directory, and fails if any target is
missing — so the docs tree cannot rot silently. Anchors are validated
too: a ``file.md#section`` (or in-page ``#section``) fragment must
match a GitHub-style slug of some heading in the target file. External
schemes (``http(s)://``, ``mailto:``) are skipped. Stdlib only; run
from anywhere:

    python tools/check_links.py [root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
FENCE_RE = re.compile(r"^\s*(```|~~~)")
SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", "node_modules",
             ".pytest_cache", "results"}
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def slugify(heading: str) -> str:
    """GitHub's anchor algorithm: strip markup, lowercase, drop
    punctuation, spaces -> hyphens. (Duplicate -1/-2 suffixes are
    handled by the caller.)"""
    # strip code/emphasis markers but keep literal underscores: GitHub
    # slugs `BENCH_*.json` as bench_json (word chars survive)
    s = re.sub(r"[`*]", "", heading)
    s = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", s)  # linked headings
    s = s.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def anchors_of(text: str) -> set[str]:
    """Every anchor a markdown file exposes (headings outside code
    fences, with GitHub's duplicate suffixing), plus explicit
    ``<a name=...>`` / ``id=...`` anchors."""
    out: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    for m in re.finditer(r"<a\s+(?:name|id)=[\"']([^\"']+)[\"']", text):
        out.add(m.group(1))
    return out


def iter_markdown(root: Path):
    for path in sorted(root.rglob("*.md")):
        if any(part in SKIP_DIRS or part.startswith(".")
               for part in path.relative_to(root).parts[:-1]):
            continue
        yield path


def check_file(path: Path, root: Path,
               anchor_cache: dict[Path, set[str]]) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")

    def anchors(p: Path) -> set[str]:
        if p not in anchor_cache:
            anchor_cache[p] = anchors_of(p.read_text(encoding="utf-8"))
        return anchor_cache[p]

    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES):
            continue
        rel, _, frag = target.partition("#")
        resolved = (path.parent / rel).resolve() if rel else path.resolve()
        line = text.count("\n", 0, m.start()) + 1
        where = path.relative_to(root)
        if not resolved.exists():
            errors.append(f"{where}:{line}: broken link -> {target}")
            continue
        if frag:
            if resolved.suffix.lower() != ".md" or resolved.is_dir():
                continue  # anchors into non-markdown targets: not ours
            if frag.lower() not in anchors(resolved):
                errors.append(
                    f"{where}:{line}: broken anchor -> {target} "
                    f"(no heading slugs to '#{frag}' in "
                    f"{resolved.relative_to(root) if resolved.is_relative_to(root) else resolved})"
                )
    return errors


def main(argv: list[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else Path.cwd()
    errors = []
    n_files = 0
    anchor_cache: dict[Path, set[str]] = {}
    for md in iter_markdown(root):
        n_files += 1
        errors.extend(check_file(md, root, anchor_cache))
    for err in errors:
        print(err)
    print(f"checked {n_files} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
