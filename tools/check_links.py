#!/usr/bin/env python3
"""Verify that relative markdown links in the repo resolve to real files.

Scans every tracked-tree ``*.md`` (skipping hidden and cache dirs) for
inline links/images ``[text](target)``, resolves each relative target
against the containing file's directory, and fails if any target is
missing — so the docs tree cannot rot silently. External schemes
(``http(s)://``, ``mailto:``) and pure in-page anchors (``#...``) are
skipped; a ``file.md#section`` target is checked for the file only
(anchor names are not validated). Stdlib only; run from anywhere:

    python tools/check_links.py [root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", "node_modules",
             ".pytest_cache", "results"}
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def iter_markdown(root: Path):
    for path in sorted(root.rglob("*.md")):
        if any(part in SKIP_DIRS or part.startswith(".")
               for part in path.relative_to(root).parts[:-1]):
            continue
        yield path


def check_file(path: Path, root: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            line = text.count("\n", 0, m.start()) + 1
            errors.append(
                f"{path.relative_to(root)}:{line}: broken link -> {target}"
            )
    return errors


def main(argv: list[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else Path.cwd()
    errors = []
    n_files = 0
    for md in iter_markdown(root):
        n_files += 1
        errors.extend(check_file(md, root))
    for err in errors:
        print(err)
    print(f"checked {n_files} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
