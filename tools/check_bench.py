#!/usr/bin/env python3
"""Validate ``BENCH_*.json`` artifacts against the schema contract in
``docs/benchmarks.md``.

Checks, per artifact: the ``benchmark``/``results`` envelope, the
per-record required keys for that benchmark (section-discriminated for
``fleet`` and ``serve``, mode-discriminated for ``tiering``), the bit-verified flag
where the schema defines one (``serve``, ``tiering``, ``migration`` —
it must be present *and* truthy: capacity/speedup numbers from dropped data are
worse than no numbers), and that no NaN/Inf leaked anywhere in the
payload. Stdlib only; CI runs it right after the bench-smoke runs:

    python tools/check_bench.py BENCH_*.json
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

# required keys per record, keyed by benchmark (and discriminator)
FLEET_SECTIONS = {
    "fleet_vs_loop": {"tenants", "chain", "method", "fleet_us", "loop_us",
                      "speedup", "fleet_mpages_s", "mean_lookups"},
    "resolver": {"tenants", "chain", "method", "format", "resolve_us",
                 "mpages_s", "mean_lookups"},
}
MAINTENANCE_KEYS = {"mode", "tenants", "chain", "k", "ticks",
                    "worst_tick_ms", "mean_tick_ms", "p50_tick_ms",
                    "quanta_reclaimed", "final_mean_chain"}
SERVE_SECTIONS = {
    "serve_step": {"section", "format", "depth", "batch", "resolver",
                   "host_us", "fleet_us", "speedup", "verified"},
    "decode": {"section", "format", "depth", "batch", "resolver",
               "tables_us", "fused_us", "speedup", "verified"},
}
TIERING_KEYS = {"mode", "depth", "tenants_live", "pool_rows", "page_size",
                "worst_tick_ms", "mean_tick_ms", "ticks", "rows_demoted",
                "rows_promoted", "host_rows", "stw_demote_ms", "verified"}
TIERING_TIERED_KEYS = TIERING_KEYS | {"promote_wave_ms", "ratio_vs_baseline"}
MIGRATION_KEYS = {"depth", "n_pages", "page_size", "rows_hot", "rows_cold",
                  "blob_kb", "export_ms", "import_ms", "verify_ms",
                  "detach_ms", "roundtrip_ms", "verified"}
PREFIX_SECTIONS = {
    "capacity": {"section", "format", "n_seqs", "n_prefixes",
                 "prefix_tokens", "suffix_tokens", "dedup_blocks",
                 "baseline_blocks", "blocks_ratio", "golden_blocks_shared",
                 "dedup_blocks_saved", "verified"},
    "ttft": {"section", "format", "n_concurrent", "n_prefixes",
             "prefix_tokens", "suffix_tokens", "dedup_admit_ms",
             "baseline_admit_ms", "speedup", "token_agreement",
             "golden_hits", "dedup_blocks_saved", "verified"},
}

# benchmarks whose records carry a bit-verified flag that must hold
VERIFIED_BENCHMARKS = {"serve", "tiering", "migration", "prefix"}


def _bad_floats(obj, path: str = "$") -> list[str]:
    if isinstance(obj, float) and not math.isfinite(obj):
        return [f"{path}: non-finite value {obj!r}"]
    if isinstance(obj, dict):
        return [e for k, v in obj.items()
                for e in _bad_floats(v, f"{path}.{k}")]
    if isinstance(obj, list):
        return [e for i, v in enumerate(obj)
                for e in _bad_floats(v, f"{path}[{i}]")]
    return []


def _record_keys(benchmark: str, rec: dict) -> set[str] | None:
    """Required keys for one record, or None if the benchmark is unknown
    (unknown artifacts get only the envelope + NaN checks)."""
    if benchmark == "fleet":
        section = rec.get("section")
        if section not in FLEET_SECTIONS:
            return {"section"}  # forces a "missing/unknown section" error
        return FLEET_SECTIONS[section] | {"section"}
    if benchmark == "maintenance":
        return MAINTENANCE_KEYS
    if benchmark == "serve":
        section = rec.get("section")
        if section not in SERVE_SECTIONS:
            return {"section"}  # forces a "missing/unknown section" error
        return SERVE_SECTIONS[section]
    if benchmark == "prefix":
        section = rec.get("section")
        if section not in PREFIX_SECTIONS:
            return {"section"}  # forces a "missing/unknown section" error
        return PREFIX_SECTIONS[section]
    if benchmark == "tiering":
        return (TIERING_TIERED_KEYS if rec.get("mode") == "tiered"
                else TIERING_KEYS)
    if benchmark == "migration":
        return MIGRATION_KEYS
    return None


def check_artifact(path: Path) -> list[str]:
    errors: list[str] = []
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable artifact: {e}"]

    if not isinstance(payload, dict):
        return [f"{path}: top level must be an object"]
    benchmark = payload.get("benchmark")
    if not isinstance(benchmark, str):
        errors.append(f"{path}: missing/invalid 'benchmark' key")
        benchmark = ""
    results = payload.get("results")
    if not isinstance(results, list) or not results:
        errors.append(f"{path}: 'results' must be a non-empty list")
        results = []

    for i, rec in enumerate(results):
        if not isinstance(rec, dict):
            errors.append(f"{path}: results[{i}] is not an object")
            continue
        required = _record_keys(benchmark, rec)
        if required is not None:
            missing = sorted(required - rec.keys())
            if missing:
                errors.append(
                    f"{path}: results[{i}] missing keys {missing} "
                    f"(benchmark={benchmark!r})")
        if benchmark in VERIFIED_BENCHMARKS and "verified" in rec \
                and not rec["verified"]:
            errors.append(
                f"{path}: results[{i}] verified={rec['verified']!r} — "
                "the cell's numbers are not bit-verified")

    errors.extend(_bad_floats(payload, f"{path}:$"))
    return errors


def main(argv: list[str]) -> int:
    paths = [Path(a) for a in argv[1:]]
    if not paths:
        print("usage: check_bench.py BENCH_*.json", file=sys.stderr)
        return 2
    errors = []
    for p in paths:
        errs = check_artifact(p)
        errors.extend(errs)
        print(f"{p}: {'OK' if not errs else f'{len(errs)} error(s)'}")
    for e in errors:
        print(e)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
