"""The paper's workload end-to-end: long chains, dd reads, streaming.

    PYTHONPATH=src:. python examples/chainstore_demo.py
"""

import time

import jax

from benchmarks.common import build_chain
from repro.core import store


def dd(chain, method):
    t0 = time.perf_counter()
    jax.block_until_ready(store.materialize(chain, method=method))
    return time.perf_counter() - t0


def main():
    print(f"{'chain':>6s} {'vanilla MB/s':>14s} {'sQEMU MB/s':>12s} {'gain':>6s}")
    for n in (1, 16, 64, 128):
        chv = build_chain(n, scalable=False)
        chs = build_chain(n, scalable=True)
        mb = chv.spec.n_pages * chv.spec.page_size * 4 / 2**20
        dd(chv, "vanilla"); dd(chs, "direct")  # warmup/compile
        tv = min(dd(chv, "vanilla") for _ in range(3))
        ts = min(dd(chs, "direct") for _ in range(3))
        print(f"{n:6d} {mb/tv:14.0f} {mb/ts:12.0f} {tv/ts:5.1f}x")

    # streaming: the provider's chain-compaction job
    ch = build_chain(96, scalable=True)
    before = store.materialize(ch)
    t0 = time.perf_counter()
    ch = store.stream(ch, merge_upto=80, copy_data=True)
    dt = time.perf_counter() - t0
    assert bool(jax.numpy.allclose(before, store.materialize(ch)))
    print(f"\nstreaming: 96 -> {store.chain_length(ch)} files in {dt*1e3:.0f} ms, "
          f"reads unchanged")


if __name__ == "__main__":
    main()
