"""Serving with COW prefix sharing: one system prompt, many forks.

    PYTHONPATH=src python examples/serve_forked.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import Engine


def main():
    cfg = dataclasses.replace(
        get_config("qwen2-7b"), n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=4096)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    system_prompt = np.arange(40) % cfg.vocab_size  # shared 40-token prefix

    for scalable in (True, False):
        eng = Engine(cfg, params, scalable=scalable, n_blocks=256,
                     block_size=8, max_blocks_per_seq=32)
        root = eng.add_request(system_prompt)
        forks = [eng.fork_request(root) for _ in range(6)]
        for _ in range(5):
            eng.step()
        st = eng.memory_stats()
        label = "scalable (direct tables)" if scalable else "vanilla (chain walk)"
        independent = (len(forks) + 1) * (len(system_prompt) // 8 + 1)
        print(f"{label}: 7 sequences, blocks_in_use={st['blocks_in_use']} "
              f"(independent copies would need ~{independent}), "
              f"table lookups={st['lookups']}")


if __name__ == "__main__":
    main()
