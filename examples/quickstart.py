"""Quickstart: the snapshot-chain store in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import resolve, store

# A virtual disk of 1024 pages x 64 floats, scalable (sQEMU) format.
chain = store.create(n_pages=1024, page_size=64, max_chain=32)

# Write some pages, snapshot, overwrite a few (COW), snapshot again.
key = jax.random.PRNGKey(0)
ids = jnp.arange(0, 256, dtype=jnp.int32)
chain = store.write(chain, ids, jax.random.normal(key, (256, 64)))
chain = store.snapshot(chain)
chain = store.write(chain, ids[:32], jnp.ones((32, 64)))
chain = store.snapshot(chain)
chain = store.write(chain, ids[:8], 2 * jnp.ones((8, 64)))
print(f"chain length: {store.chain_length(chain)}")

# Reads are identical through either resolver; the cost is not. The
# "pallas_*" methods run the same strategies as Pallas kernels (compiled
# on TPU, interpret mode elsewhere — see docs/kernels.md).
data_direct, res_d = store.read(chain, ids, method="direct")
data_walk, res_v = store.read(chain, ids, method="vanilla")
data_kernel, _ = store.read(chain, ids, method="pallas_direct")
assert jnp.allclose(data_direct, data_walk)
assert jnp.allclose(data_direct, data_kernel)
print(f"direct lookups:  {int(res_d.lookups.sum())}  (1 per page — sQEMU)")
print(f"owners live in snapshots: {sorted(set(int(o) for o in res_d.owner))}")

# A vanilla-format chain pays the walk; converting it enables direct access.
vch = store.create(n_pages=1024, page_size=64, max_chain=32, scalable=False)
vch = store.write(vch, ids, jax.random.normal(key, (256, 64)))
for _ in range(8):
    vch = store.snapshot(vch)
walk = resolve.resolve_vanilla(vch, ids)
print(f"vanilla-format walk lookups: {int(walk.lookups.sum())} "
      f"(chain length {store.chain_length(vch)})")
vch2 = store.convert_to_scalable(vch)
direct = resolve.resolve_direct(vch2, ids)
print(f"after conversion: {int(direct.lookups.sum())} lookups")

# Streaming compacts the chain without changing any read.
before = store.materialize(chain)
chain = store.stream(chain, merge_upto=1)
assert jnp.allclose(before, store.materialize(chain))
print(f"streamed to length {store.chain_length(chain)}; content preserved")
