"""End-to-end training driver: ~100M-param LM with incremental snapshot
checkpoints, a simulated crash, restart, and goodput accounting.

    PYTHONPATH=src python examples/train_e2e.py            # scaled (CPU)
    PYTHONPATH=src python examples/train_e2e.py --full     # ~100M, 300 steps
"""

import argparse
import dataclasses


from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (minutes on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    base = get_config("qwen2.5-3b")
    if args.full:
        cfg = dataclasses.replace(
            base, n_layers=10, d_model=640, n_heads=10, n_kv_heads=2,
            head_dim=64, d_ff=2560, vocab_size=32768)
        steps, seq, batch = args.steps or 300, 256, 8
    else:
        cfg = dataclasses.replace(
            base, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab_size=512)
        steps, seq, batch = args.steps or 60, 64, 4
    print(f"model: {cfg.param_count()/1e6:.1f}M params, {steps} steps")

    model = get_model(cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch)
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=max(steps // 10, 1),
                         page_size=4096)
    trainer = Trainer(model, AdamWConfig(lr=3e-4, warmup_steps=20,
                                         total_steps=steps), dcfg, tcfg)

    # run to ~60%, crash, restore from the snapshot chain, finish
    crash_at = int(steps * 0.6)
    try:
        trainer.run(crash_after=crash_at)
    except RuntimeError as e:
        print(f"!! {e} — restoring from the checkpoint chain")
    resumed = trainer.resume(method="direct")
    print(f"resumed at step {resumed} "
          f"(chain length {int(trainer.ckpt.chain.length)})")
    report = trainer.run()

    losses = trainer.losses
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"(decreased: {losses[-1] < losses[0]})")
    print(f"goodput={report['goodput']:.2f} "
          f"straggler_steps={report['straggler_steps']}")
    saves = [e for e in trainer.events if e["kind"] == "ckpt"]
    total_mb = sum(s["bytes_written"] for s in saves) / 2**20
    print(f"checkpoints: {len(saves)} delta saves, {total_mb:.1f} MiB total, "
          f"final chain length {report['ckpt_chain_length']}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
