"""optim subsystem."""
