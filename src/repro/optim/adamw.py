"""AdamW with fp32 state, cosine schedule, global-norm clipping.

State shards exactly like the parameters (the launcher applies the same
PartitionSpecs), which with the fsdp axis in the param rules gives
ZeRO-style sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / cfg.warmup_steps)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * cos


def init(params: Any):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def apply(cfg: AdamWConfig, grads: Any, state: Any, params: Any):
    """Returns (new_params, new_state, diagnostics)."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, dict(m=new_m, v=new_v, step=step), dict(
        grad_norm=gnorm, lr=lr
    )
