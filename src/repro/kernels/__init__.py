"""Pallas TPU kernels (validated in interpret mode on CPU):

* chain_resolve — vanilla first-hit chain walk vs sQEMU direct lookup
* cow_gather — resolved-page HBM gather (scalar-prefetch DMA pattern)
* paged_attention — decode attention over paged KV w/ direct block tables
* stream_merge — streaming-compaction select-latest merge
"""
