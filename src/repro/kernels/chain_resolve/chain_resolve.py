"""Pallas TPU kernels for chain resolution — single-chain and fleet layouts.

The vanilla path is the paper's chain walk recast for a TPU: a first-hit
reduction over the chain axis instead of a per-request pointer chase, with
bytes-touched cost O(C) per page. The direct kernel touches one layer:
O(1). The ``*_fleet_pallas`` entry points extend both to the stacked
(T, C, P) multi-tenant layout of ``core.fleet``: the grid runs over the
tenant axis, per-tenant chain ``length`` is prefetched as a scalar (the
direct kernel's BlockSpec index_map uses it to stage *only* each tenant's
active layer), and the fleet kernels consume the packed L2 words of
``core.format`` directly — the kernel reads the actual table format, as
the paper's sQemu data plane does.

See ``docs/kernels.md`` for the full cost model, tiling constraints
(pages on the 128-lane axis, chain axis in sublanes) and the
interpret-mode CI story.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import format as fmt

PAGE_TILE = 512  # lanes per grid step (4 × 128)


def _vanilla_kernel(length_ref, alloc_ref, ptr_ref, owner_ref, out_ptr_ref):
    c = alloc_ref.shape[0]
    length = length_ref[0]

    owner = jnp.full((1, alloc_ref.shape[1]), -1, jnp.int32)
    ptr = jnp.zeros((1, alloc_ref.shape[1]), jnp.uint32)

    def body(i, carry):
        owner, ptr = carry
        # walk from the active volume (length-1) downwards
        layer = length - 1 - i
        valid = (layer >= 0) & (layer < c)
        idx = jnp.maximum(layer, 0)
        a = (alloc_ref[idx, :] != 0) & valid
        hit = a & (owner[0] < 0)
        owner = owner.at[0].set(jnp.where(hit, layer, owner[0]))
        ptr = ptr.at[0].set(jnp.where(hit, ptr_ref[idx, :], ptr[0]))
        return owner, ptr

    owner, ptr = jax.lax.fori_loop(0, c, body, (owner, ptr))
    owner_ref[...] = owner
    out_ptr_ref[...] = ptr


@partial(jax.jit, static_argnames=("interpret",))
def resolve_vanilla_pallas(alloc, ptrs, length, *, interpret: bool = True):
    """alloc/ptrs: (C, N); length scalar. N must be a multiple of 128."""
    c, n = alloc.shape
    n_tiles = pl.cdiv(n, PAGE_TILE)
    tile = min(PAGE_TILE, n)
    owner, ptr = pl.pallas_call(
        _vanilla_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((c, tile), lambda i, ln: (0, i)),
                pl.BlockSpec((c, tile), lambda i, ln: (0, i)),
            ],
            out_specs=[
                pl.BlockSpec((1, tile), lambda i, ln: (0, i)),
                pl.BlockSpec((1, tile), lambda i, ln: (0, i)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.uint32),
        ],
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32)[None], alloc.astype(jnp.uint32),
      ptrs.astype(jnp.uint32))
    return owner[0], ptr[0]


def _direct_kernel(alloc_ref, bfi_ref, ptr_ref, owner_ref, out_ptr_ref):
    a = alloc_ref[...] != 0
    owner_ref[...] = jnp.where(a, bfi_ref[...].astype(jnp.int32), -1)
    out_ptr_ref[...] = jnp.where(a, ptr_ref[...], jnp.uint32(0))


@partial(jax.jit, static_argnames=("interpret",))
def resolve_direct_pallas(alloc_active, bfi_active, ptrs_active, *,
                          interpret: bool = True):
    """All inputs (N,). One VMEM pass over the active layer only."""
    n = alloc_active.shape[0]
    tile = min(PAGE_TILE, n)
    spec2 = pl.BlockSpec((1, tile), lambda i: (0, i))
    owner, ptr = pl.pallas_call(
        _direct_kernel,
        grid=(pl.cdiv(n, tile),),
        in_specs=[spec2, spec2, spec2],
        out_specs=[spec2, spec2],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.uint32),
        ],
        interpret=interpret,
    )(alloc_active.astype(jnp.uint32)[None], bfi_active.astype(jnp.uint32)[None],
      ptrs_active.astype(jnp.uint32)[None])
    return owner[0], ptr[0]


# -- stacked (T, C, P) fleet layout ------------------------------------------


def _vanilla_fleet_kernel(length_ref, w0_ref, owner_ref, hit_ref):
    c = w0_ref.shape[1]
    width = w0_ref.shape[2]
    length = length_ref[pl.program_id(0)]

    owner = jnp.full((1, width), -1, jnp.int32)
    hit = jnp.zeros((1, width), jnp.uint32)

    def body(i, carry):
        owner, hit = carry
        # walk from the tenant's active volume (length-1) downwards
        layer = length - 1 - i
        valid = (layer >= 0) & (layer < c)
        idx = jnp.maximum(layer, 0)
        w = w0_ref[0, idx, :]
        a = (w & jnp.uint32(fmt.FLAG_ALLOCATED)) != 0
        first = a & valid & (owner[0] < 0)
        owner = owner.at[0].set(jnp.where(first, layer, owner[0]))
        hit = hit.at[0].set(jnp.where(first, w, hit[0]))
        return owner, hit

    owner, hit = jax.lax.fori_loop(0, c, body, (owner, hit))
    owner_ref[...] = owner
    hit_ref[...] = hit


@partial(jax.jit, static_argnames=("interpret",))
def resolve_vanilla_fleet_pallas(w0, lengths, *, interpret: bool = True):
    """Stacked first-hit chain walk over every tenant's full page table.

    ``w0``: (T, C, P) uint32 — packed L2 word0 (``core.format`` layout:
    ALLOCATED/ZERO flags + pool ptr); ``lengths``: (T,) int32. P should be
    a multiple of 128 (``ops.resolve_vanilla_fleet`` pads).

    Returns ``(owner (T, P) int32 [-1 if absent], hit (T, P) uint32)``
    where ``hit`` is the owning layer's raw word0 (0 where absent).
    """
    t, c, p = w0.shape
    tile = min(PAGE_TILE, p)
    owner, hit = pl.pallas_call(
        _vanilla_fleet_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(t, pl.cdiv(p, tile)),
            in_specs=[
                pl.BlockSpec((1, c, tile), lambda ti, pi, ln: (ti, 0, pi)),
            ],
            out_specs=[
                pl.BlockSpec((1, tile), lambda ti, pi, ln: (ti, pi)),
                pl.BlockSpec((1, tile), lambda ti, pi, ln: (ti, pi)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((t, p), jnp.int32),
            jax.ShapeDtypeStruct((t, p), jnp.uint32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), w0.astype(jnp.uint32))
    return owner, hit


def _direct_fleet_kernel(length_ref, w0_ref, w1_ref, owner_ref, h0_ref, h1_ref):
    w0 = w0_ref[0, 0, :]
    w1 = w1_ref[0, 0, :]
    alloc = (w0 & jnp.uint32(fmt.FLAG_ALLOCATED)) != 0
    bfi = (w1 & jnp.uint32(fmt.BFI_MASK)).astype(jnp.int32)
    owner_ref[...] = jnp.where(alloc, bfi, -1)[None]
    h0_ref[...] = w0[None]
    h1_ref[...] = w1[None]


@partial(jax.jit, static_argnames=("interpret",))
def resolve_direct_fleet_pallas(w0, w1, lengths, *, interpret: bool = True):
    """Stacked sQEMU direct access: one layer per tenant, picked by the
    BlockSpec index_map from the prefetched ``lengths`` — only each
    tenant's active layer is ever staged into VMEM, so the bytes-touched
    cost is O(1) per page regardless of chain length.

    ``w0``/``w1``: (T, C, P) uint32 packed L2 words; ``lengths``: (T,).

    Returns ``(owner (T, P) int32 [-1 if unallocated], h0 (T, P) uint32,
    h1 (T, P) uint32)`` — the active layer's raw entry words.
    """
    t, c, p = w0.shape
    tile = min(PAGE_TILE, p)
    in_spec = pl.BlockSpec((1, 1, tile), lambda ti, pi, ln: (ti, ln[ti] - 1, pi))
    out_spec = pl.BlockSpec((1, tile), lambda ti, pi, ln: (ti, pi))
    owner, h0, h1 = pl.pallas_call(
        _direct_fleet_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(t, pl.cdiv(p, tile)),
            in_specs=[in_spec, in_spec],
            out_specs=[out_spec, out_spec, out_spec],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((t, p), jnp.int32),
            jax.ShapeDtypeStruct((t, p), jnp.uint32),
            jax.ShapeDtypeStruct((t, p), jnp.uint32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), w0.astype(jnp.uint32), w1.astype(jnp.uint32))
    return owner, h0, h1
