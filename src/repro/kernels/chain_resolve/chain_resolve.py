"""Pallas TPU kernels for chain resolution.

The vanilla path is the paper's chain walk recast for a TPU: instead of a
pointer chase per request (host Qemu), a *batch* of page ids is resolved by
a first-hit reduction over the chain axis. The allocation bitmap tile
(C × Tn) is staged HBM→VMEM by the BlockSpec; the chain axis is reduced
in-kernel with a fori loop, so the bytes-touched cost remains O(C) per
page — faithfully the vanilla cost model. The direct kernel touches one
layer: O(1).

Tiling: pages are tiled along the lane dimension (multiples of 128); the
chain axis lives in the sublane dimension of the same VMEM tile.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PAGE_TILE = 512  # lanes per grid step (4 × 128)


def _vanilla_kernel(length_ref, alloc_ref, ptr_ref, owner_ref, out_ptr_ref):
    c = alloc_ref.shape[0]
    length = length_ref[0]

    owner = jnp.full((1, alloc_ref.shape[1]), -1, jnp.int32)
    ptr = jnp.zeros((1, alloc_ref.shape[1]), jnp.uint32)

    def body(i, carry):
        owner, ptr = carry
        # walk from the active volume (length-1) downwards
        layer = length - 1 - i
        valid = (layer >= 0) & (layer < c)
        idx = jnp.maximum(layer, 0)
        a = (alloc_ref[idx, :] != 0) & valid
        hit = a & (owner[0] < 0)
        owner = owner.at[0].set(jnp.where(hit, layer, owner[0]))
        ptr = ptr.at[0].set(jnp.where(hit, ptr_ref[idx, :], ptr[0]))
        return owner, ptr

    owner, ptr = jax.lax.fori_loop(0, c, body, (owner, ptr))
    owner_ref[...] = owner
    out_ptr_ref[...] = ptr


@partial(jax.jit, static_argnames=("interpret",))
def resolve_vanilla_pallas(alloc, ptrs, length, *, interpret: bool = True):
    """alloc/ptrs: (C, N); length scalar. N must be a multiple of 128."""
    c, n = alloc.shape
    n_tiles = pl.cdiv(n, PAGE_TILE)
    tile = min(PAGE_TILE, n)
    owner, ptr = pl.pallas_call(
        _vanilla_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((c, tile), lambda i, ln: (0, i)),
                pl.BlockSpec((c, tile), lambda i, ln: (0, i)),
            ],
            out_specs=[
                pl.BlockSpec((1, tile), lambda i, ln: (0, i)),
                pl.BlockSpec((1, tile), lambda i, ln: (0, i)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.uint32),
        ],
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32)[None], alloc.astype(jnp.uint32),
      ptrs.astype(jnp.uint32))
    return owner[0], ptr[0]


def _direct_kernel(alloc_ref, bfi_ref, ptr_ref, owner_ref, out_ptr_ref):
    a = alloc_ref[...] != 0
    owner_ref[...] = jnp.where(a, bfi_ref[...].astype(jnp.int32), -1)
    out_ptr_ref[...] = jnp.where(a, ptr_ref[...], jnp.uint32(0))


@partial(jax.jit, static_argnames=("interpret",))
def resolve_direct_pallas(alloc_active, bfi_active, ptrs_active, *,
                          interpret: bool = True):
    """All inputs (N,). One VMEM pass over the active layer only."""
    n = alloc_active.shape[0]
    tile = min(PAGE_TILE, n)
    spec2 = pl.BlockSpec((1, tile), lambda i: (0, i))
    owner, ptr = pl.pallas_call(
        _direct_kernel,
        grid=(pl.cdiv(n, tile),),
        in_specs=[spec2, spec2, spec2],
        out_specs=[spec2, spec2],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.uint32),
        ],
        interpret=interpret,
    )(alloc_active.astype(jnp.uint32)[None], bfi_active.astype(jnp.uint32)[None],
      ptrs_active.astype(jnp.uint32)[None])
    return owner[0], ptr[0]
