"""Jitted wrappers for chain resolution.

Single-chain wrappers dispatch Pallas on TPU and the jnp oracle elsewhere.
The fleet (``*_fleet``) wrappers *always* run the Pallas kernel — compiled
on TPU, interpret mode elsewhere — so CPU CI exercises the exact kernel
code path (the oracles in ``ref.py`` stay the independent pin the test
suite compares against).
"""

from __future__ import annotations

import jax

from repro.kernels.chain_resolve import ref
from repro.kernels.chain_resolve.chain_resolve import (
    resolve_direct_fleet_pallas,
    resolve_direct_pallas,
    resolve_vanilla_fleet_pallas,
    resolve_vanilla_pallas,
)
from repro.kernels.common import pad_lanes as _pad_pages


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_vanilla(alloc, ptrs, length):
    """(C, N) chain walk. Dispatches Pallas (TPU) / interpret-validated ref."""
    if _on_tpu():
        alloc_p, n = _pad_pages(alloc)
        ptrs_p, _ = _pad_pages(ptrs)
        owner, ptr = resolve_vanilla_pallas(alloc_p, ptrs_p, length,
                                            interpret=False)
        return owner[:n], ptr[:n]
    return ref.resolve_vanilla_ref(alloc, ptrs, length)


def resolve_direct(alloc_active, bfi_active, ptrs_active):
    if _on_tpu():
        a, n = _pad_pages(alloc_active)
        b, _ = _pad_pages(bfi_active)
        p, _ = _pad_pages(ptrs_active)
        owner, ptr = resolve_direct_pallas(a, b, p, interpret=False)
        return owner[:n], ptr[:n]
    return ref.resolve_direct_ref(alloc_active, bfi_active, ptrs_active)


def resolve_vanilla_fleet(w0, lengths):
    """Stacked (T, C, P) chain walk. Always the Pallas kernel (interpret
    off-TPU); pads the page axis to a 128-lane multiple."""
    w0_p, n = _pad_pages(w0)
    owner, hit = resolve_vanilla_fleet_pallas(w0_p, lengths,
                                              interpret=not _on_tpu())
    return owner[:, :n], hit[:, :n]


def resolve_direct_fleet(w0, w1, lengths):
    """Stacked (T, C, P) direct lookup of each tenant's active layer.
    Always the Pallas kernel (interpret off-TPU); pads the page axis."""
    w0_p, n = _pad_pages(w0)
    w1_p, _ = _pad_pages(w1)
    owner, h0, h1 = resolve_direct_fleet_pallas(w0_p, w1_p, lengths,
                                                interpret=not _on_tpu())
    return owner[:, :n], h0[:, :n], h1[:, :n]
