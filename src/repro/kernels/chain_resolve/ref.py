"""Pure-jnp oracle for chain resolution (vanilla first-hit scan + direct)."""

from __future__ import annotations

import jax.numpy as jnp


def resolve_vanilla_ref(alloc, ptrs, length):
    """First allocated layer from the top of the chain.

    alloc: (C, N) bool/int — per-layer allocation map for N pages.
    ptrs:  (C, N) uint32 — per-layer pool pointers.
    length: scalar int — live chain length (layers >= length are dead).

    Returns (owner (N,) int32 [-1 if absent], ptr (N,) uint32).
    """
    c = alloc.shape[0]
    live = jnp.arange(c, dtype=jnp.int32)[:, None] < length
    a = (alloc != 0) & live
    idx = jnp.arange(c, dtype=jnp.int32)[:, None]
    owner = jnp.max(jnp.where(a, idx, -1), axis=0)
    ptr = jnp.take_along_axis(ptrs, jnp.maximum(owner, 0)[None], axis=0)[0]
    ptr = jnp.where(owner >= 0, ptr, 0)
    return owner.astype(jnp.int32), ptr.astype(jnp.uint32)


def resolve_direct_ref(alloc_active, bfi_active, ptrs_active):
    """sQEMU direct access: one lookup of the active volume's entries.

    All inputs (N,). Returns (owner (N,) int32, ptr (N,) uint32).
    """
    owner = jnp.where(alloc_active != 0, bfi_active.astype(jnp.int32), -1)
    ptr = jnp.where(alloc_active != 0, ptrs_active, 0)
    return owner.astype(jnp.int32), ptr.astype(jnp.uint32)
