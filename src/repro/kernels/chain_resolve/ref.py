"""Pure-jnp oracle for chain resolution (vanilla first-hit scan + direct),
for both the single-chain (C, N) and the stacked fleet (T, C, P) layouts."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import format as fmt


def resolve_vanilla_ref(alloc, ptrs, length):
    """First allocated layer from the top of the chain.

    alloc: (C, N) bool/int — per-layer allocation map for N pages.
    ptrs:  (C, N) uint32 — per-layer pool pointers.
    length: scalar int — live chain length (layers >= length are dead).

    Returns (owner (N,) int32 [-1 if absent], ptr (N,) uint32).
    """
    c = alloc.shape[0]
    live = jnp.arange(c, dtype=jnp.int32)[:, None] < length
    a = (alloc != 0) & live
    idx = jnp.arange(c, dtype=jnp.int32)[:, None]
    owner = jnp.max(jnp.where(a, idx, -1), axis=0)
    ptr = jnp.take_along_axis(ptrs, jnp.maximum(owner, 0)[None], axis=0)[0]
    ptr = jnp.where(owner >= 0, ptr, 0)
    return owner.astype(jnp.int32), ptr.astype(jnp.uint32)


def resolve_direct_ref(alloc_active, bfi_active, ptrs_active):
    """sQEMU direct access: one lookup of the active volume's entries.

    All inputs (N,). Returns (owner (N,) int32, ptr (N,) uint32).
    """
    owner = jnp.where(alloc_active != 0, bfi_active.astype(jnp.int32), -1)
    ptr = jnp.where(alloc_active != 0, ptrs_active, 0)
    return owner.astype(jnp.int32), ptr.astype(jnp.uint32)


def resolve_vanilla_fleet_ref(w0, lengths):
    """Stacked first-hit walk over packed word0 tables.

    w0: (T, C, P) uint32 — L2 word0 per ``core.format``.
    lengths: (T,) int32 — per-tenant live chain length.

    Returns (owner (T, P) int32 [-1 if absent], hit (T, P) uint32 — the
    owning layer's raw word0, 0 where absent).
    """
    c = w0.shape[1]
    layers = jnp.arange(c, dtype=jnp.int32)[None, :, None]
    live = layers < lengths[:, None, None]
    alloc = ((w0 & jnp.uint32(fmt.FLAG_ALLOCATED)) != 0) & live
    owner = jnp.max(jnp.where(alloc, layers, -1), axis=1)     # (T, P)
    hit = jnp.take_along_axis(w0, jnp.maximum(owner, 0)[:, None, :],
                              axis=1)[:, 0]
    hit = jnp.where(owner >= 0, hit, jnp.uint32(0))
    return owner.astype(jnp.int32), hit.astype(jnp.uint32)


def resolve_direct_fleet_ref(w0, w1, lengths):
    """Stacked direct access: each tenant's active layer, one lookup.

    w0/w1: (T, C, P) uint32 packed L2 words; lengths: (T,) int32.

    Returns (owner (T, P) int32 [-1 if unallocated], h0 (T, P) uint32,
    h1 (T, P) uint32 — the active layer's raw entry words).
    """
    active = (lengths.astype(jnp.int32) - 1)[:, None, None]
    h0 = jnp.take_along_axis(w0, active, axis=1)[:, 0]        # (T, P)
    h1 = jnp.take_along_axis(w1, active, axis=1)[:, 0]
    alloc = (h0 & jnp.uint32(fmt.FLAG_ALLOCATED)) != 0
    bfi = (h1 & jnp.uint32(fmt.BFI_MASK)).astype(jnp.int32)
    owner = jnp.where(alloc, bfi, -1)
    return owner.astype(jnp.int32), h0.astype(jnp.uint32), h1.astype(jnp.uint32)
