"""Pure-jnp oracle for the resolved-page gather (the 'dd read' hot path)."""

from __future__ import annotations

import jax.numpy as jnp


def gather_ref(pool, rows, found):
    """pool: (R, P); rows: (B,) int32; found: (B,) bool → (B, P).

    Unresolved pages read as zeros (Qcow2 unallocated-cluster semantics).
    """
    safe = jnp.where(found, rows, 0).astype(jnp.int32)
    data = pool[safe]
    return jnp.where(found[:, None], data, jnp.zeros_like(data))


def gather_fleet_ref(pool, rows, found):
    """pool: (R, P); rows: (T, B) int32; found: (T, B) bool → (T, B, P).

    The pool is global across tenants, so the fleet gather is one fancy
    index — unresolved pages read as zeros, as in the single-chain case.
    """
    safe = jnp.where(found, rows, 0).astype(jnp.int32)
    data = pool[safe]
    return jnp.where(found[..., None], data, jnp.zeros_like(data))
