"""Pallas TPU kernel: gather resolved pages from the HBM pool.

The classic scalar-prefetch dynamic-gather pattern: the resolved row ids
are prefetched as scalars, and each grid step's BlockSpec index_map picks
the pool row to stage into VMEM — the gather is free at the memory-system
level (one HBM→VMEM DMA per page, no scatter/gather ALU work). Rows of
``page_size`` are lane-aligned (pad to 128).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(rows_ref, found_ref, pool_ref, out_ref):
    i = pl.program_id(0)
    ok = found_ref[i] != 0
    out_ref[...] = jnp.where(ok, pool_ref[...], jnp.zeros_like(pool_ref[...]))


@partial(jax.jit, static_argnames=("interpret",))
def gather_pallas(pool, rows, found, *, interpret: bool = True):
    """pool: (R, P); rows: (B,); found: (B,) → (B, P)."""
    r, p = pool.shape
    b = rows.shape[0]
    safe_rows = jnp.where(found, rows, 0).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, p), lambda i, rows_ref, found_ref: (rows_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, p), lambda i, rows_ref, found_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, p), pool.dtype),
        interpret=interpret,
    )(safe_rows, found.astype(jnp.int32), pool)
    return out


def _gather_fleet_kernel(rows_ref, found_ref, pool_ref, out_ref):
    t = pl.program_id(0)
    i = pl.program_id(1)
    ok = found_ref[t, i] != 0
    out_ref[...] = jnp.where(
        ok, pool_ref[...], jnp.zeros_like(pool_ref[...])
    )[None]


@partial(jax.jit, static_argnames=("interpret",))
def gather_fleet_pallas(pool, rows, found, *, interpret: bool = True):
    """Stacked fleet gather: the pool is global, so one kernel serves every
    tenant. ``pool``: (R, P); ``rows``/``found``: (T, B) → (T, B, P).

    Same scalar-prefetch pattern as the single-chain gather, with a
    (tenant, request) grid: each grid step's index_map picks the pool row
    for one tenant's request out of the prefetched (T, B) row table.
    """
    r, p = pool.shape
    t, b = rows.shape
    safe_rows = jnp.where(found, rows, 0).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, b),
        in_specs=[
            pl.BlockSpec((1, p), lambda ti, bi, rows_ref, found_ref:
                         (rows_ref[ti, bi], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, p), lambda ti, bi, rows_ref, found_ref:
                               (ti, bi, 0)),
    )
    out = pl.pallas_call(
        _gather_fleet_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, b, p), pool.dtype),
        interpret=interpret,
    )(safe_rows, found.astype(jnp.int32), pool)
    return out
