"""Jitted wrapper: full read path = resolve + gather."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cow_gather import ref
from repro.kernels.cow_gather.cow_gather import gather_pallas


def gather(pool, rows, found):
    if jax.default_backend() == "tpu":
        p = pool.shape[1]
        pad = (-p) % 128
        pool_p = jnp.pad(pool, ((0, 0), (0, pad))) if pad else pool
        out = gather_pallas(pool_p, rows, found, interpret=False)
        return out[:, :p]
    return ref.gather_ref(pool, rows, found)
