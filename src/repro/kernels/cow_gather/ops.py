"""Jitted wrapper: full read path = resolve + gather.

``gather`` (single chain) dispatches Pallas on TPU and the jnp oracle
elsewhere; ``gather_fleet`` always runs the Pallas kernel (interpret mode
off-TPU) so CPU CI exercises the kernel path — ``ref.gather_fleet_ref``
stays the independent oracle.
"""

from __future__ import annotations

import jax

from repro.kernels.common import pad_lanes
from repro.kernels.cow_gather import ref
from repro.kernels.cow_gather.cow_gather import gather_fleet_pallas, gather_pallas


def _pad_pool(pool):
    return pad_lanes(pool, axis=1)


def gather(pool, rows, found):
    if jax.default_backend() == "tpu":
        pool_p, p = _pad_pool(pool)
        out = gather_pallas(pool_p, rows, found, interpret=False)
        return out[:, :p]
    return ref.gather_ref(pool, rows, found)


def gather_fleet(pool, rows, found):
    """Fleet read gather: (R, P) pool + (T, B) rows/found → (T, B, P).
    Always the Pallas kernel (interpret off-TPU); pads the page axis."""
    pool_p, p = _pad_pool(pool)
    out = gather_fleet_pallas(
        pool_p, rows, found, interpret=jax.default_backend() != "tpu"
    )
    return out[..., :p]
