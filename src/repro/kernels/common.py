"""Shared kernel-plane helpers.

Every Pallas kernel in this package tiles its page axis over the TPU's
128-wide lane dimension, so each ``ops`` wrapper needs the same
pad-to-lane-multiple step before the ``pallas_call`` and the same
un-pad slice after it. ``pad_lanes`` is that one helper; the per-kernel
wrappers (``chain_resolve``, ``cow_gather``, ``paged_attention``) all
share it instead of carrying private copies.
"""

from __future__ import annotations

import jax.numpy as jnp

#: TPU vector lane width — the tiling unit of every kernel's page axis.
LANES = 128


def pad_lanes(x, axis: int = -1, multiple: int = LANES):
    """Zero-pad ``axis`` of ``x`` up to a multiple of ``multiple``.

    Returns ``(padded, original_size)`` so callers can slice the kernel
    output back to the caller-visible extent. Zero padding is safe for
    every kernel here: a zero L2 word has ``FLAG_ALLOCATED`` unset (the
    walk skips it), and padded pool/output lanes are sliced away.
    """
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis % x.ndim] = (0, pad)
        x = jnp.pad(x, widths)
    return x, n
