"""Jitted wrappers for paged decode attention.

Hot-path policy (``docs/kernels.md``): the wrappers the serving engine's
jitted decode step calls — ``paged_attention`` and ``fused_attention`` —
dispatch the compiled Pallas kernel on TPU and the jnp oracle elsewhere
(interpret mode inside a per-layer decode loop would be pure overhead).
``fused_chain_attention`` is the *always-kernel* wrapper: compiled on
TPU, interpret mode off-TPU, so CPU CI executes the exact fused kernel
body — the same split ``chain_resolve`` makes between its single-chain
and fleet wrappers.
"""

from __future__ import annotations

import jax

from repro.kernels.common import pad_lanes
from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.paged_attention import (
    fused_chain_attention_pallas,
    paged_attention_pallas,
)


def paged_attention(q, pool_k, pool_v, tables, lengths):
    if jax.default_backend() == "tpu":
        return paged_attention_pallas(q, pool_k, pool_v, tables, lengths,
                                      interpret=False)
    return ref.paged_attention_ref(q, pool_k, pool_v, tables, lengths)


def fused_chain_attention(q, pool_k, pool_v, w0, chain_lengths, tenants,
                          kv_lengths):
    """Fused chain-resolve attention over the stacked (T, C, P) index.
    Always the Pallas kernel (interpret off-TPU); pads the page axis to
    a 128-lane multiple — padded lanes are unallocated words the walk
    resolves to holes, so they never contribute."""
    w0_p, _ = pad_lanes(w0)
    return fused_chain_attention_pallas(
        q, pool_k, pool_v, w0_p, chain_lengths, tenants, kv_lengths,
        interpret=jax.default_backend() != "tpu")


def fused_attention(q, pool_k, pool_v, w0, chain_lengths, tenants,
                    kv_lengths):
    """The decode hot path's fused dispatch: compiled kernel on TPU, the
    composed oracle elsewhere. The caller guarantees a lane-aligned page
    axis (``core.fleet.fused_layout_ok`` — the engine's auto-selection
    rule), so no padding happens on the TPU path."""
    if jax.default_backend() == "tpu":
        return fused_chain_attention_pallas(
            q, pool_k, pool_v, w0, chain_lengths, tenants, kv_lengths,
            interpret=False)
    return ref.fused_chain_attention_ref(
        q, pool_k, pool_v, w0, chain_lengths, tenants, kv_lengths)
