"""Jitted wrapper for paged decode attention (Pallas on TPU, ref on CPU)."""

from __future__ import annotations

import jax

from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.paged_attention import paged_attention_pallas


def paged_attention(q, pool_k, pool_v, tables, lengths):
    if jax.default_backend() == "tpu":
        return paged_attention_pallas(q, pool_k, pool_v, tables, lengths,
                                      interpret=False)
    return ref.paged_attention_ref(q, pool_k, pool_v, tables, lengths)
