"""Pure-jnp oracle for paged decode attention (direct block tables)."""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, pool_k, pool_v, tables, lengths):
    """q: (B, H, D); pool_k/v: (nb, bs, Hkv, D); tables: (B, M) int32
    (-1 = absent); lengths: (B,) int32. Returns (B, H, D) in q.dtype.

    GQA: H = Hkv * G. Softmax in f32.
    """
    b, h, d = q.shape
    nb, bs, hkv, _ = pool_k.shape
    m = tables.shape[1]
    g = h // hkv

    safe = jnp.maximum(tables, 0)
    k = pool_k[safe].reshape(b, m * bs, hkv, d)       # (B, S, Hkv, D)
    v = pool_v[safe].reshape(b, m * bs, hkv, d)
    pos = jnp.arange(m * bs)[None, :]                 # (1, S)
    mask = (pos < lengths[:, None]) & jnp.repeat(tables >= 0, bs, axis=1)

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jnp.where(
        jnp.any(mask[:, None, None, :], -1, keepdims=True),
        jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True)),
        0.0,
    )
    probs = probs / jnp.maximum(jnp.sum(probs, -1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
