"""Pure-jnp oracles for paged decode attention.

``paged_attention_ref`` consumes a pre-materialized direct block table;
``fused_chain_attention_ref`` pins the fused kernel instead: it composes
the stacked first-hit chain walk (``kernels.chain_resolve.ref``) with
``paged_attention_ref``, so the fused kernel's in-grid walk + pool DMA
is asserted against two already-pinned oracles rather than a third
independent implementation.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import resolve as resolve_lib
from repro.kernels.chain_resolve import ref as chain_ref


def paged_attention_ref(q, pool_k, pool_v, tables, lengths):
    """q: (B, H, D); pool_k/v: (nb, bs, Hkv, D); tables: (B, M) int32
    (-1 = absent); lengths: (B,) int32. Returns (B, H, D) in q.dtype.

    GQA: H = Hkv * G. Softmax in f32.
    """
    b, h, d = q.shape
    nb, bs, hkv, _ = pool_k.shape
    m = tables.shape[1]
    g = h // hkv

    safe = jnp.maximum(tables, 0)
    k = pool_k[safe].reshape(b, m * bs, hkv, d)       # (B, S, Hkv, D)
    v = pool_v[safe].reshape(b, m * bs, hkv, d)
    pos = jnp.arange(m * bs)[None, :]                 # (1, S)
    mask = (pos < lengths[:, None]) & jnp.repeat(tables >= 0, bs, axis=1)

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jnp.where(
        jnp.any(mask[:, None, None, :], -1, keepdims=True),
        jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True)),
        0.0,
    )
    probs = probs / jnp.maximum(jnp.sum(probs, -1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def fused_tables_ref(w0, chain_lengths, tenants):
    """Resolve the batch's direct block tables from the stacked index.

    ``w0``: (T, C, P) uint32 packed L2 word0; ``chain_lengths``: (T,)
    int32; ``tenants``: (B,) int32. Returns (B, P) int32 tables with -1
    holes — only the batch's tenant rows are walked (O(B·C·P), matching
    the fused kernel's grid, not the fleet-wide O(T·C·P) resolve).
    """
    owner, hit = chain_ref.resolve_vanilla_fleet_ref(
        w0[tenants], chain_lengths[tenants])
    return resolve_lib.tables_from_hits(owner, hit)


def fused_chain_attention_ref(q, pool_k, pool_v, w0, chain_lengths,
                              tenants, kv_lengths):
    """Oracle for the fused kernel: the pinned chain-walk oracle feeds
    the pinned table-consuming attention oracle. Same signature contract
    as ``fused_chain_attention_pallas``; returns (B, H, D) in q.dtype."""
    tables = fused_tables_ref(w0, chain_lengths, tenants)
    return paged_attention_ref(q, pool_k, pool_v, tables, kv_lengths)
