"""Pallas TPU kernels: decode attention over a paged KV pool.

``paged_attention_pallas`` is the serving-side payoff of the paper's
*direct access* principle: the block table handed to it is the flattened
(copy-forward) table, so each grid step DMAs exactly one physical KV
block HBM→VMEM via the scalar-prefetched index map — no fork-chain
walking anywhere near the attention inner loop. It requires that table
to have been materialized (resolved, synced, assembled, re-shipped) by
the host first.

``fused_chain_attention_pallas`` removes that materialization step: the
kernel receives the *stacked fleet index itself* — the packed L2 word0
stacks of ``core.fleet`` plus per-tenant chain lengths — and performs
the first-hit chain walk of ``chain_resolve`` inside the attention grid,
then DMAs each KV block straight out of the shared pool through the
resolved row id. A tenant with ``max_chain == 1`` (the scalable/sQEMU
format) degenerates to the O(1) active-layer direct lookup; deeper
stacks pay the paper's O(chain) walk once per batch row, amortized over
every page lane at once. See ``docs/kernels.md`` for the cost model.

Grid: (batch, kv_blocks); the kv-block axis is innermost and sequential on
a TPU core, so the online-softmax running state (m, l, acc) lives in VMEM
scratch across iterations. f32 accumulation, bf16 I/O.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import format as fmt


def _paged_attn_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, out_ref,
                       m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    bs = k_ref.shape[1]
    hkv = k_ref.shape[2]
    d = q_ref.shape[2]
    h = q_ref.shape[1]
    g = h // hkv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    # tables entries are -1 only past ceil(length/bs), so the length mask
    # alone is sufficient (entries were clamped to 0 for the DMA index map)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = pos < length                                  # (1,1,bs)

    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, d)
    k = k_ref[0].astype(jnp.float32)                      # (bs, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    scores = jnp.einsum("hgd,shd->hgs", q, k)             # (Hkv,G,bs)
    scores = scores / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(valid.reshape(1, 1, bs), scores, -jnp.inf)

    m_prev = m_ref[...]                                   # (Hkv,G,1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    p = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (
        acc_ref[...] * alpha
        + jnp.einsum("hgs,shd->hgd", p, v)
    )
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[...] = (acc_ref[...] / denom).reshape(1, h, d).astype(
            out_ref.dtype
        )


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q, pool_k, pool_v, tables, lengths, *,
                           interpret: bool = True):
    """q: (B, H, D); pool_k/v: (nb, bs, Hkv, D); tables: (B, M); lengths (B,)."""
    b, h, d = q.shape
    nb, bs, hkv, _ = pool_k.shape
    m_blocks = tables.shape[1]
    g = h // hkv
    safe_tables = jnp.maximum(tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, m_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, j, t, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, j, t, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _paged_attn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(safe_tables, lengths.astype(jnp.int32), q,
      pool_k.reshape(nb, bs, hkv, d), pool_v.reshape(nb, bs, hkv, d))


# -- fused chain-resolve attention -------------------------------------------


def _fused_chain_attn_kernel(tenants_ref, chain_len_ref, kvlen_ref,
                             q_ref, w0_ref, kp_ref, vp_ref, out_ref,
                             rows_ref, m_ref, l_ref, acc_ref,
                             k_buf, v_buf, sem_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)
    c = w0_ref.shape[1]
    p = w0_ref.shape[2]
    bs, hkv, d = k_buf.shape
    h = q_ref.shape[1]
    g = h // hkv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # the fused chain walk: one vectorized first-hit scan over this
        # batch row's tenant stack resolves every page lane at once and
        # parks the pool rows in VMEM scratch for the whole kv sweep.
        # C == 1 (scalable tenants) makes this the O(1) direct lookup.
        length = chain_len_ref[tenants_ref[b]]
        owner = jnp.full((1, p), -1, jnp.int32)
        rows = jnp.zeros((1, p), jnp.int32)

        def body(i, carry):
            owner, rows = carry
            # walk from the tenant's active volume (length-1) downwards
            layer = length - 1 - i
            valid = (layer >= 0) & (layer < c)
            idx = jnp.maximum(layer, 0)
            w = w0_ref[0, idx, :]
            a = (w & jnp.uint32(fmt.FLAG_ALLOCATED)) != 0
            first = a & valid & (owner[0] < 0)
            owner = owner.at[0].set(jnp.where(first, layer, owner[0]))
            rows = rows.at[0].set(jnp.where(
                first, (w & jnp.uint32(fmt.PTR_MASK)).astype(jnp.int32),
                rows[0]))
            return owner, rows

        owner, rows = jax.lax.fori_loop(0, c, body, (owner, rows))
        rows_ref[...] = jnp.where(owner >= 0, rows, -1)

    # this block's resolved pool row: a masked reduce over the parked walk
    # result (VMEM has no dynamic scalar lane indexing)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, p), 1)
    rows = rows_ref[...]
    row = jnp.sum(jnp.where(lane == j, rows, 0))
    hole = row < 0
    row_safe = jnp.maximum(row, 0)

    # KV pages come straight from the shared pool through the resolved
    # row id — the pool stays in HBM (ANY) and each grid step DMAs one
    # block; no host-materialized table anywhere on this path
    ck = pltpu.make_async_copy(kp_ref.at[row_safe], k_buf, sem_ref.at[0])
    cv = pltpu.make_async_copy(vp_ref.at[row_safe], v_buf, sem_ref.at[1])
    ck.start()
    cv.start()
    ck.wait()
    cv.wait()

    kvlen = kvlen_ref[b]
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = (pos < kvlen) & jnp.logical_not(hole)      # (1,1,bs)

    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, d)
    k = k_buf[...].astype(jnp.float32)                 # (bs, Hkv, D)
    v = v_buf[...].astype(jnp.float32)
    scores = jnp.einsum("hgd,shd->hgs", q, k)          # (Hkv,G,bs)
    scores = scores / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(valid.reshape(1, 1, bs), scores, -jnp.inf)

    m_prev = m_ref[...]                                # (Hkv,G,1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    pmat = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pmat, axis=-1, keepdims=True)
    acc_ref[...] = (
        acc_ref[...] * alpha
        + jnp.einsum("hgs,shd->hgd", pmat, v)
    )
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[...] = (acc_ref[...] / denom).reshape(1, h, d).astype(
            out_ref.dtype
        )


@partial(jax.jit, static_argnames=("interpret",))
def fused_chain_attention_pallas(q, pool_k, pool_v, w0, chain_lengths,
                                 tenants, kv_lengths, *,
                                 interpret: bool = True):
    """Decode attention that walks the snapshot chain inside the kernel.

    ``q``: (B, H, D); ``pool_k``/``pool_v``: (nb, bs, Hkv, D) shared KV
    pool; ``w0``: (T, C, P) uint32 — the stacked fleet index's packed L2
    word0 (``core.format`` layout), P a multiple of 128
    (``ops.fused_chain_attention`` pads); ``chain_lengths``: (T,) int32
    per-tenant chain length; ``tenants``: (B,) int32 batch-row → tenant
    row; ``kv_lengths``: (B,) int32 tokens to attend over. Returns
    (B, H, D) in q.dtype.

    Unallocated pages (first-hit miss) contribute nothing; a batch row
    whose tenant resolves no pages within ``kv_lengths`` outputs zeros.
    """
    b, h, d = q.shape
    nb, bs, hkv, _ = pool_k.shape
    t, c, p = w0.shape
    g = h // hkv
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, p),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, j, tn, cl, kl: (b, 0, 0)),
            pl.BlockSpec((1, c, p), lambda b, j, tn, cl, kl: (tn[b], 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, j, tn, cl, kl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, p), jnp.int32),
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
            pltpu.VMEM((bs, hkv, d), pool_k.dtype),
            pltpu.VMEM((bs, hkv, d), pool_v.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        _fused_chain_attn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(tenants.astype(jnp.int32), chain_lengths.astype(jnp.int32),
      kv_lengths.astype(jnp.int32), q, w0.astype(jnp.uint32),
      pool_k.reshape(nb, bs, hkv, d), pool_v.reshape(nb, bs, hkv, d))
