"""Pallas TPU kernel: decode attention over a paged KV pool.

The serving-side payoff of the paper's *direct access* principle: the
block table handed to this kernel is the flattened (copy-forward) table,
so each grid step DMAs exactly one physical KV block HBM→VMEM via the
scalar-prefetched index map — no fork-chain walking anywhere near the
attention inner loop.

Grid: (batch, kv_blocks); the kv-block axis is innermost and sequential on
a TPU core, so the online-softmax running state (m, l, acc) lives in VMEM
scratch across iterations. f32 accumulation, bf16 I/O.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_attn_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, out_ref,
                       m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    bs = k_ref.shape[1]
    hkv = k_ref.shape[2]
    d = q_ref.shape[2]
    h = q_ref.shape[1]
    g = h // hkv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    # tables entries are -1 only past ceil(length/bs), so the length mask
    # alone is sufficient (entries were clamped to 0 for the DMA index map)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = pos < length                                  # (1,1,bs)

    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, d)
    k = k_ref[0].astype(jnp.float32)                      # (bs, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    scores = jnp.einsum("hgd,shd->hgs", q, k)             # (Hkv,G,bs)
    scores = scores / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(valid.reshape(1, 1, bs), scores, -jnp.inf)

    m_prev = m_ref[...]                                   # (Hkv,G,1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    p = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (
        acc_ref[...] * alpha
        + jnp.einsum("hgs,shd->hgd", p, v)
    )
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[...] = (acc_ref[...] / denom).reshape(1, h, d).astype(
            out_ref.dtype
        )


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q, pool_k, pool_v, tables, lengths, *,
                           interpret: bool = True):
    """q: (B, H, D); pool_k/v: (nb, bs, Hkv, D); tables: (B, M); lengths (B,)."""
    b, h, d = q.shape
    nb, bs, hkv, _ = pool_k.shape
    m_blocks = tables.shape[1]
    g = h // hkv
    safe_tables = jnp.maximum(tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, m_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, j, t, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, j, t, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _paged_attn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(safe_tables, lengths.astype(jnp.int32), q,
      pool_k.reshape(nb, bs, hkv, d), pool_v.reshape(nb, bs, hkv, d))
