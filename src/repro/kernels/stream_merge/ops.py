"""Jitted wrapper for the streaming merge."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stream_merge import ref
from repro.kernels.stream_merge.stream_merge import merge_pallas


def merge(alloc, ptrs, bfi=None):
    if jax.default_backend() == "tpu":
        n = alloc.shape[1]
        pad = (-n) % 128
        if pad:
            alloc = jnp.pad(alloc, ((0, 0), (0, pad)))
            ptrs = jnp.pad(ptrs, ((0, 0), (0, pad)))
        found, ptr, src = merge_pallas(alloc, ptrs, interpret=False)
        return found[:n], ptr[:n], src[:n]
    return ref.merge_ref(alloc, ptrs, bfi)
