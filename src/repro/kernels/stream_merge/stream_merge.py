"""Pallas TPU kernel: streaming compaction metadata merge.

Same VMEM tiling as chain_resolve (pages on lanes, layers on sublanes) but
the reduction direction is bottom-up with last-write-wins, producing the
merged base layer the provider's streaming job writes (paper §4.1). The
data movement of streaming is the separate ``cow_gather`` pass over the
winning pointers.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAGE_TILE = 512


def _merge_kernel(alloc_ref, ptr_ref, found_ref, out_ptr_ref, src_ref):
    k = alloc_ref.shape[0]
    n = alloc_ref.shape[1]
    src = jnp.full((1, n), -1, jnp.int32)
    ptr = jnp.zeros((1, n), jnp.uint32)

    def body(i, carry):
        src, ptr = carry
        a = alloc_ref[i, :] != 0
        src = src.at[0].set(jnp.where(a, i, src[0]))     # last write wins
        ptr = ptr.at[0].set(jnp.where(a, ptr_ref[i, :], ptr[0]))
        return src, ptr

    src, ptr = jax.lax.fori_loop(0, k, body, (src, ptr))
    found_ref[...] = (src >= 0).astype(jnp.uint32)
    out_ptr_ref[...] = ptr
    src_ref[...] = src


@partial(jax.jit, static_argnames=("interpret",))
def merge_pallas(alloc, ptrs, *, interpret: bool = True):
    """alloc/ptrs: (K, N), N a multiple of 128 → (found, ptr, src)."""
    k, n = alloc.shape
    tile = min(PAGE_TILE, n)
    in_spec = pl.BlockSpec((k, tile), lambda i: (0, i))
    out_spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    found, ptr, src = pl.pallas_call(
        _merge_kernel,
        grid=(pl.cdiv(n, tile),),
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.uint32),
            jax.ShapeDtypeStruct((1, n), jnp.uint32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
        ],
        interpret=interpret,
    )(alloc.astype(jnp.uint32), ptrs.astype(jnp.uint32))
    return found[0] != 0, ptr[0], src[0]
