"""Pure-jnp oracle for streaming compaction (select-latest over K layers)."""

from __future__ import annotations

import jax.numpy as jnp


def merge_ref(alloc, ptrs, bfi):
    """Merge K snapshot layers into one (paper's streaming job).

    alloc/ptrs/bfi: (K, N). For each page, take the entry of the highest
    allocated layer; the merged layer's owner becomes 0 (renumbered base).
    Returns (alloc (N,), ptr (N,), src_layer (N,) int32 [-1 if absent]).
    """
    k = alloc.shape[0]
    idx = jnp.arange(k, dtype=jnp.int32)[:, None]
    a = alloc != 0
    src = jnp.max(jnp.where(a, idx, -1), axis=0)
    found = src >= 0
    ptr = jnp.take_along_axis(ptrs, jnp.maximum(src, 0)[None], axis=0)[0]
    return (
        found,
        jnp.where(found, ptr, 0).astype(jnp.uint32),
        src.astype(jnp.int32),
    )
