"""train subsystem."""
