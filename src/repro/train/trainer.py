"""Fault-tolerant training loop on the snapshot-checkpoint chain.

Production concerns implemented here:

* **checkpoint/restart** — every ``ckpt_every`` steps the full training
  state (params, optimizer, data-pipeline step) is delta-saved into the
  snapshot chain (only dirty pages are written — ``checkpoint/``);
  ``Trainer.resume()`` restores from the chain (direct access) and
  continues from the recorded step. ``crash_after`` in ``run()`` exercises
  the path under test.
* **straggler mitigation** — a per-step deadline (EWMA × tolerance);
  overruns are logged as straggler events and counted into goodput. On a
  real fleet this signal feeds the elastic controller; here it drives the
  reported goodput metric and the test hooks.
* **streaming policy** — the checkpointer compacts its chain past the
  provider threshold (paper §3), bounding restore cost and pool growth.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.snapstore_ckpt import SnapshotCheckpointer
from repro.data import pipeline as data_lib
from repro.models.api import LM
from repro.optim import adamw
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 10
    page_size: int = 2048
    straggler_tolerance: float = 3.0
    accum_steps: int = 1
    log_every: int = 10


class Trainer:
    def __init__(self, model: LM, opt_cfg: adamw.AdamWConfig,
                 data_cfg: data_lib.DataConfig, tcfg: TrainerConfig,
                 *, seed: int = 0):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        key = jax.random.PRNGKey(seed)
        self.params = model.init(key)
        self.opt_state = adamw.init(self.params)
        self.step = 0
        self._step_fn = jax.jit(
            make_train_step(model, opt_cfg, accum_steps=tcfg.accum_steps),
            donate_argnums=(0, 1),
        )
        self.ckpt = SnapshotCheckpointer(
            self._state(), page_size=tcfg.page_size
        )
        self.events: list[dict] = []
        self._ewma: Optional[float] = None
        self.straggler_steps = 0
        self.losses: list[float] = []

    def _state(self):
        return dict(params=self.params, opt=self.opt_state,
                    step=jnp.asarray(self.step, jnp.int32))

    def _batch(self, step: int):
        cfg = self.model.cfg
        return data_lib.batch_at(
            self.data_cfg, step,
            with_frames=cfg.enc_frames if cfg.family == "encdec" else 0,
            d_model=cfg.d_model,
        )

    def run(self, *, crash_after: Optional[int] = None) -> dict:
        t_useful = 0.0
        t_total0 = time.perf_counter()
        while self.step < self.tcfg.total_steps:
            t0 = time.perf_counter()
            batch = self._batch(self.step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            self.losses.append(loss)
            dt = time.perf_counter() - t0
            t_useful += dt
            # straggler watchdog: EWMA deadline
            if self._ewma is None:
                self._ewma = dt
            deadline = self._ewma * self.tcfg.straggler_tolerance
            if dt > deadline:
                self.straggler_steps += 1
                self.events.append(dict(kind="straggler", step=self.step,
                                        dt=dt, deadline=deadline))
            self._ewma = 0.9 * self._ewma + 0.1 * dt
            self.step += 1
            if self.step % self.tcfg.ckpt_every == 0:
                st = self.ckpt.save(self._state())
                self.events.append(dict(kind="ckpt", step=self.step, **st))
            if crash_after is not None and self.step >= crash_after:
                raise RuntimeError(f"simulated crash at step {self.step}")
        wall = time.perf_counter() - t_total0
        return dict(
            steps=self.step,
            final_loss=self.losses[-1] if self.losses else float("nan"),
            goodput=t_useful / max(wall, 1e-9),
            straggler_steps=self.straggler_steps,
            ckpt_chain_length=int(self.ckpt.chain.length),
        )

    def resume(self, *, method: str = "direct") -> int:
        """Restore the latest checkpoint from the chain; returns the step."""
        state = self.ckpt.restore(method=method)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])
        return self.step
