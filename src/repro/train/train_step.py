"""The pjit training step: loss → grads → AdamW, with optional grad accum."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.api import LM
from repro.optim import adamw


def make_train_step(model: LM, opt_cfg: adamw.AdamWConfig, *,
                    accum_steps: int = 1, cast_bf16: bool = False,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``accum_steps > 1`` splits the batch along axis 0 into microbatches and
    accumulates grads in f32 (the memory knob for big train cells).
    ``cast_bf16`` casts matrix params to bf16 *before* the FSDP all-gather,
    halving both the gather wire bytes and the weight-read HBM traffic
    (the cast happens shard-local; the model's own .astype becomes a no-op).
    ``grad_shardings`` pins the grad (and accumulation-carry) sharding to
    the parameter shardings — without it XLA keeps the scan carry
    replicated and all-reduces *full-size* grads every microbatch instead
    of reduce-scattering into the FSDP shards (measured 1.7 TB/dev → see
    EXPERIMENTS.md §Perf cell A).
    """

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def loss_fn(params, batch):
        if cast_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.ndim >= 2 and p.dtype == jnp.float32 else p,
                params,
            )
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin(grads)
        else:
            def micro(i):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:])[i],
                    batch,
                )

            def body(carry, i):
                loss_acc, grads_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro(i))
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grads_acc,
                    _pin(g)
                )
                return (loss_acc + l, _pin(grads_acc)), None

            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zeros), jnp.arange(accum_steps)
            )
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        params, opt_state, diag = adamw.apply(opt_cfg, grads, opt_state, params)
        metrics = dict(loss=loss, **diag)
        return params, opt_state, metrics

    return train_step


def init_state(model: LM, key):
    params = model.init(key)
    return params, adamw.init(params)
