"""Paged KV cache with COW sequence forking — the fleet-backed serving plane.

vLLM-style block pool, plus the paper's two designs at the block-table
level:

* **vanilla fork** (vQemu analogue): a forked sequence starts with an empty
  block table and a parent pointer; resolving block *b* walks the fork
  chain until an ancestor that owns it is found — O(fork depth) per block.
* **scalable fork** (sQEMU analogue): fork copies the parent's *resolved*
  table forward, with an ``owner`` id per block (the ``backing_file_index``
  analogue) — O(1) per block, and the attention kernel receives a direct
  block table (``kernels/paged_attention``).

COW: appending to a block owned by an ancestor first copies it into a
fresh pool block (cluster copy-on-write). Pool blocks are refcounted so
shared prefixes are stored once (paper Fig 7: base-image sharing).

**Fleet backing.** The cache is a thin sequence-lifecycle façade over a
``core.fleet.ChainFleet``: every unfreed sequence occupies one tenant row
of a stacked (T, C, P) index, where P = ``max_blocks_per_seq`` logical
pages, the L2 ``ptr`` field holds KV pool block ids, and — for vanilla
caches — chain layer *i* of a tenant is the block table of ancestor *i*
on that sequence's fork path (root first, self on top). Fork is the
fleet's per-tenant snapshot into a fresh tenant (``fork_tenant`` /
``clone_tenant``), COW-prepare is one batched metadata stamp
(``stamp_entries``), and block-table materialization for a decode step is
ONE stacked fleet resolve (``resolve_*_stacked`` — the Pallas kernel
plane on lane-aligned layouts, the vmapped gather otherwise). Because a
vanilla fork's layers are *copies* of live ancestors' tables, every write
by a node is propagated to each tenant stack holding a copy of its layer
(the ``_occupants`` registry) — so the stacked index always resolves
bit-identically to the live parent-pointer walk.

**Fused decode path.** On lane-aligned pools the engine skips table
materialization entirely: ``prepare_step_fused`` derives the COW-prepare
decisions from a *narrow* resolve of just the batch's write columns and
returns a ``FusedStepPlan`` — the stacked index words, per-tenant chain
lengths and three (N,) vectors — that the fused attention kernel
(``kernels/paged_attention``) consumes directly, walking the chain
inside the decode grid. ``prepare_step`` remains the fallback for
non-lane-aligned pools and the oracle the fused path is tested against.

Host-side state survives as (a) the refcount/tombstone lifecycle (the
block allocator and ``free_seq`` contract are unchanged) and (b) the
numpy resolver ``_resolve_oracle`` — retained purely as the test oracle
the fleet plane is asserted bit-identical against. No serving-path
operation walks fork chains on the host.

The fleet's lease allocator is idle here (KV blocks come from the cache's
refcounted free list; shared-prefix blocks cross tenant boundaries, which
leases forbid) — ``free_tenant`` still retires each sequence's tenant row
on ``free_seq``. Never run ``fleet.stream_tenants``/``compact`` on this
fleet: forked tenants share rows by design.

**Tiering.** A parked sequence's exclusively-owned KV blocks can spill to
host memory (``demote_seq``): the data leaves ``pool_k``/``pool_v`` (the
blocks return to the free list), the owning L2 entries are stamped with
the ``FLAG_COLD`` residency bit, and the stacked resolve reports the
cold positions. Promotion is lazy and on-demand: every table-producing
path (``prepare_step``, ``batched_tables``, ``block_table``, the write
preps) transparently calls ``promote_seq`` on involved sequences first,
so a resumed deep fork pays its transfer on the first step it actually
joins rather than stalling ``Engine.resume_request``. Shared-prefix
blocks (refcount > 1) and blocks visible to forked descendants never
spill — exclusivity is what makes the host copy the unique owner.
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fleet as fleet_lib
from repro.core import format as fmt


class FusedStepPlan(NamedTuple):
    """Device inputs for one fused decode step (``prepare_step_fused``).

    The fused attention kernel walks the stacked fleet index itself, so
    instead of a materialized (N, max_blocks) table the step ships the
    index *references* plus three (N,) host-assembled vectors — the only
    per-step host→device traffic on this path.
    """

    l2: jax.Array             # (T, C, P, 2) uint32 — the stacked index,
                              # already device-resident (no transfer)
    chain_lengths: jax.Array  # (T,) int32 per-tenant chain length (device)
    tenants: jax.Array        # (N,) int32 batch row → tenant row
    lengths: jax.Array        # (N,) int32 pre-advance sequence lengths
    write_blocks: jax.Array   # (N,) int32 COW-prepared in-step write target


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    block_size: int = 16
    n_blocks: int = 256
    max_blocks_per_seq: int = 64
    dtype: object = jnp.bfloat16


@dataclasses.dataclass
class _Seq:
    sid: int
    table: np.ndarray        # (max_blocks,) int32 pool block or -1 (own layer)
    owner: np.ndarray        # (max_blocks,) int32 owning sid (bfi analogue)
    parent: Optional[int]
    length: int
    refs: set = dataclasses.field(default_factory=set)  # blocks we refcount
    freed: bool = False      # tombstone: freed but pinned by live children
    children: int = 0        # seqs (live or tombstoned) naming us as parent
    tenant: Optional[int] = None  # fleet row while unfreed; None once freed
    path: tuple = ()         # fork ancestry, root first, self last
    cold: set = dataclasses.field(default_factory=set)  # host-spilled blks
    golden: bool = False     # frozen shared-prefix base (register_golden)


#: Initial fleet geometry; both axes grow by doubling on demand.
_INIT_TENANTS = 8
_INIT_CHAIN = 8


@partial(jax.jit, static_argnames=("method",))
def _fleet_tables(fleet, page_ids, method):
    """ONE stacked fleet resolve → (4, T, P) int32: per tenant row, the
    flat block table (-1 holes), the owner field (chain layer for the
    walk, bfi-sid for direct), the per-page lookup cost, and the tier
    residency bit (1 where the hit is host-spilled — its table id is
    stale and must not reach the attention kernel unpromoted)."""
    res = fleet_lib.get_resolver(method)(fleet, page_ids)
    table = jnp.where(res.found, res.ptr.astype(jnp.int32), -1)
    return jnp.stack([table, res.owner.astype(jnp.int32),
                      res.lookups.astype(jnp.int32),
                      res.cold.astype(jnp.int32)])


class PagedKVCache:
    def __init__(self, cfg: PagedKVConfig, *, scalable: bool = True,
                 resolver: str = "auto"):
        self.cfg = cfg
        self.scalable = scalable
        fleet_lib.get_resolver(resolver)   # fail fast on unknown methods
        self.resolver = resolver
        shape = (cfg.n_layers, cfg.n_blocks, cfg.block_size,
                 cfg.n_kv_heads, cfg.head_dim)
        self.pool_k = jnp.zeros(shape, cfg.dtype)
        self.pool_v = jnp.zeros(shape, cfg.dtype)
        self._free = list(range(cfg.n_blocks - 1, -1, -1))
        self._ref = np.zeros(cfg.n_blocks, np.int32)
        self._reserved: set[int] = set()
        self._seqs: dict[int, _Seq] = {}
        self._next_sid = 0
        self.lookup_count = 0  # fork-chain index consultations (Fig 13 analogue)
        # the metadata plane: one tenant row per unfreed sequence
        self.fleet = fleet_lib.create(
            self._fleet_spec(_INIT_TENANTS,
                             1 if scalable else _INIT_CHAIN),
            scalable=scalable,
        )
        self._free_tenants = list(range(_INIT_TENANTS - 1, -1, -1))
        # node sid -> [(tenant, layer)] tenant stacks holding a live copy
        # of that node's table (its own row plus, for vanilla, every
        # descendant's): the fan-out set of a COW-prepare stamp
        self._occupants: dict[int, list[tuple[int, int]]] = {}
        self._grid = None      # cached (T, P) page-id grid for the resolve
        # host tier: sid -> {block index -> (k, v) numpy (L, bs, H, D)}
        # for sequences whose exclusive blocks were demoted (demote_seq)
        self._cold_kv: dict[int, dict[int, tuple]] = {}
        self.demoted_blocks = 0   # lifetime spills (tier metrics)
        self.promoted_blocks = 0  # lifetime un-spills
        # golden prefixes: sid -> content hash (register_golden); the
        # flagged sequences are frozen — forked, never written or freed
        self._golden: dict[int, str] = {}

    # -- fleet geometry -------------------------------------------------------

    def _fleet_spec(self, n_tenants: int, max_chain: int) -> fleet_lib.FleetSpec:
        p = self.cfg.max_blocks_per_seq
        return fleet_lib.FleetSpec(
            n_tenants=n_tenants,
            n_pages=p,
            page_size=1,             # metadata plane: KV data lives in pool_k/v
            max_chain=max_chain,
            pool_capacity=self.cfg.n_blocks,
            lease_quantum=self.cfg.n_blocks,   # lease allocator idle here
            l2_per_table=p,
            slice_len=1,
        )

    def _grow_fleet(self, *, n_tenants: int | None = None,
                    max_chain: int | None = None) -> None:
        """Double a fleet axis (tenant rows / chain depth), copying the
        stacked index into the larger geometry. Amortized: O(log) growths
        over a cache's lifetime, each a couple of device copies."""
        old = self.fleet
        t0, c0 = old.spec.n_tenants, old.spec.max_chain
        t1, c1 = n_tenants or t0, max_chain or c0
        nf = fleet_lib.create(self._fleet_spec(t1, c1),
                              scalable=self.scalable)
        self.fleet = dataclasses.replace(
            nf,
            l1=nf.l1.at[:t0, :c0].set(old.l1),
            l2=nf.l2.at[:t0, :c0].set(old.l2),
            length=nf.length.at[:t0].set(old.length),
            scalable=nf.scalable.at[:t0].set(old.scalable),
            cold_count=nf.cold_count.at[:t0].set(old.cold_count),
        )
        self._free_tenants = (list(range(t1 - 1, t0 - 1, -1))
                              + self._free_tenants)
        self._grid = None

    def _claim_tenant(self) -> int:
        if not self._free_tenants:
            self._grow_fleet(n_tenants=self.fleet.spec.n_tenants * 2)
        return self._free_tenants.pop()

    def _page_grid(self) -> jax.Array:
        spec = self.fleet.spec
        if self._grid is None or self._grid.shape != (spec.n_tenants,
                                                      spec.n_pages):
            self._grid = jnp.broadcast_to(
                jnp.arange(spec.n_pages, dtype=jnp.int32)[None],
                (spec.n_tenants, spec.n_pages),
            )
        return self._grid

    def _resolve_all(self):
        """One stacked fleet resolve of every tenant's full block table;
        one device→host sync. Returns host (tables, owners, lookups,
        colds), each (T, P) int32."""
        # the ONE designed sync per decode step: everything downstream
        # (COW-prepare mask, attention tables) derives from this result
        out = np.array(_fleet_tables(self.fleet, self._page_grid(),  # fleetlint: disable=FL002
                                     self.resolver))
        return out[0], out[1], out[2], out[3]

    def _resolve_tenant(self, t: int):
        """Stacked fleet resolve restricted to one tenant row (a 1-tenant
        view of the same arrays), so single-sequence ops — ``append``,
        ``prepare_write``, ``block_table``, ``fork`` — don't pay the
        fleet-wide O(T·C·P) resolve. Returns host (table, owner,
        lookups, cold), each (P,) int32."""
        fl = self.fleet
        view = dataclasses.replace(
            fl,
            spec=self._fleet_spec(1, fl.spec.max_chain),
            l1=fl.l1[t:t + 1],
            l2=fl.l2[t:t + 1],
            lease_index=fl.lease_index[t:t + 1],
            lease_count=fl.lease_count[t:t + 1],
            alloc_count=fl.alloc_count[t:t + 1],
            length=fl.length[t:t + 1],
            scalable=fl.scalable[t:t + 1],
            overflow=fl.overflow[t:t + 1],
            snap_dropped=fl.snap_dropped[t:t + 1],
            cold_count=fl.cold_count[t:t + 1],
        )
        grid = jnp.arange(self.cfg.max_blocks_per_seq, dtype=jnp.int32)[None]
        # single-tenant admission/fork edge, not the per-step loop: the
        # decode path itself resolves through _resolve_all
        out = np.array(_fleet_tables(view, grid, self.resolver))  # fleetlint: disable=FL002
        return out[0, 0], out[1, 0], out[2, 0], out[3, 0]

    def _count_lookups(self, seq: _Seq, table_row: np.ndarray,
                       lookups_row: np.ndarray) -> int:
        # bit-compatible with the oracle's accounting: sequences the
        # oracle resolves directly (scalable format, or a vanilla root
        # with no parent chain) charge one consultation per resolved
        # block; walked sequences charge the per-block chain depth the
        # resolver reports
        if self.scalable or seq.parent is None:
            return int(np.sum(table_row >= 0)) or 1
        return int(np.sum(lookups_row))

    # -- sequence lifecycle ---------------------------------------------------

    def new_seq(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        mb = self.cfg.max_blocks_per_seq
        # the claimed slot is already a clean length-1 chain with the
        # cache's (uniform) format flag: free_seq ran free_tenant on it,
        # and freshly grown slots are created that way — no fleet op here
        t = self._claim_tenant()
        self._seqs[sid] = _Seq(
            sid, np.full(mb, -1, np.int32), np.full(mb, -1, np.int32),
            None, 0, tenant=t, path=(sid,),
        )
        self._occupants[sid] = [(t, 0)]
        return sid

    def fork(self, sid: int) -> int:
        parent = self._live_seq(sid)
        # a parked parent promotes first: the fork shares its table by
        # block id, and a spilled block's id is stale by definition
        if parent.cold:
            self.promote_seq(sid)
        child = self._next_sid
        self._next_sid += 1
        mb = self.cfg.max_blocks_per_seq
        tp, tc = parent.tenant, self._claim_tenant()
        if self.scalable:
            # sQEMU snapshot copy-forward: the child's table directly indexes
            # every ancestor-owned block (owner = the bfi analogue). The
            # parent's tenant row *is* its resolved table, so the fleet-side
            # fork is a plain row clone (depth stays 1 — O(1) resolution).
            shared = parent.table
            owner = np.where(shared >= 0, parent.owner, -1)
            owner = np.where((shared >= 0) & (owner < 0), sid, owner)
            # clone_tenant overwrites the slot's full row (stacks, length,
            # format flag), so no attach_tenant reset is needed first
            self.fleet = fleet_lib.clone_tenant(self.fleet, tp, tc)
            seq = _Seq(child, shared.copy(), owner.astype(np.int32), None,
                       parent.length, tenant=tc, path=(child,))
            self._occupants[child] = [(tc, 0)]
            self.lookup_count += int(np.sum(shared >= 0)) or 1
        else:
            # vanilla: the child's tenant stack = the parent's (one row
            # copy) + a fresh empty active layer; the resolved view for
            # the child's refcounts comes from the fleet, not a host walk
            depth = len(parent.path)
            if depth >= self.fleet.spec.max_chain:
                self._grow_fleet(
                    max_chain=max(self.fleet.spec.max_chain * 2, depth + 1)
                )
            shared, _, lookups_r, _ = self._resolve_tenant(tp)
            self.lookup_count += self._count_lookups(parent, shared,
                                                     lookups_r)
            self.fleet = fleet_lib.fork_tenant(self.fleet, tp, tc)
            seq = _Seq(child, np.full(mb, -1, np.int32),
                       np.full(mb, -1, np.int32), sid, parent.length,
                       tenant=tc, path=parent.path + (child,))
            self._occupants[child] = [(tc, depth)]
            # live ancestors keep writing their layers; register the
            # child's copies so those writes propagate (freed ancestors
            # never write again and need no registration)
            for i, anc_sid in enumerate(parent.path):
                anc = self._seqs.get(anc_sid)
                if anc is not None and not anc.freed:
                    self._occupants[anc_sid].append((tc, i))
            parent.children += 1
        # the child holds a reference on every shared block
        seq.refs = {int(b) for b in shared[shared >= 0]}
        for b in seq.refs:
            self._ref[b] += 1
        self._seqs[child] = seq
        return child

    def free_seq(self, sid: int) -> None:
        """Free a sequence, tombstoning it while forked children live.

        A vanilla-forked child resolves missing blocks through its
        ancestors' layers, so a parent cannot simply vanish while children
        exist: the refcounted blocks it owns would be lost. Freeing such a
        parent leaves a *tombstone* — the node and its block refs stay
        until the last descendant is freed, then the whole dead suffix of
        the chain is reaped at once. The fleet tenant row, by contrast, is
        released immediately (``fleet.free_tenant``): children resolve
        from their own copies of the ancestor layers, not the parent's
        row.
        """
        seq = self._live_seq(sid)
        if seq.golden:
            raise ValueError(
                f"sequence {sid} is a registered golden prefix; call "
                "release_golden(sid) before freeing it"
            )
        seq.freed = True
        t = seq.tenant
        seq.tenant = None
        self.fleet = fleet_lib.free_tenant(self.fleet, t)
        self._free_tenants.append(t)
        # a freed node never writes again, and nothing may keep stamping
        # into its (soon reused) tenant row; its host-tier spill (exclusive
        # by construction) has no other reader and is dropped with it
        self._occupants.pop(sid, None)
        self._cold_kv.pop(sid, None)
        seq.cold.clear()
        for anc_sid in seq.path[:-1]:
            occ = self._occupants.get(anc_sid)
            if occ is not None:
                self._occupants[anc_sid] = [o for o in occ if o[0] != t]
        self._reap(seq)

    def _live_seq(self, sid: int) -> _Seq:
        seq = self._seqs[sid]
        if seq.freed:
            raise KeyError(f"sequence {sid} has been freed")
        return seq

    def _reap(self, seq: _Seq) -> None:
        # Release freed nodes bottom-up: a node goes only when *nothing*
        # (live or tombstoned) still names it as parent; its removal may
        # in turn orphan a tombstoned ancestor, so walk up the chain.
        # ``children`` is maintained at fork/reap time, so retirement is
        # O(chain suffix), not O(#sequences) per free.
        while seq is not None and seq.freed and seq.children == 0:
            for b in seq.refs:
                self._ref[b] -= 1
                if self._ref[b] <= 0:
                    self._free.append(int(b))
                    self._ref[b] = 0
            del self._seqs[seq.sid]
            parent = (self._seqs.get(seq.parent)
                      if seq.parent is not None else None)
            if parent is not None:
                parent.children -= 1
            seq = parent

    # -- resolution: the retained numpy oracle --------------------------------

    def _resolve_oracle(self, sid: int):
        """Host-side resolution — the retained numpy reference.

        The serving paths resolve through the fleet (``_resolve_all``);
        this per-sequence walk survives purely so tests (and ``gather``)
        can assert the two planes bit-identical. Pure: does not touch
        ``lookup_count``. Returns ``(table, owner, lookups)``.
        """
        seq = self._seqs[sid]
        if self.scalable or seq.parent is None:
            lookups = int(np.sum(seq.table >= 0)) or 1
            return seq.table, seq.owner, lookups
        # vanilla: per block, walk up the fork chain
        mb = self.cfg.max_blocks_per_seq
        table = np.full(mb, -1, np.int32)
        owner = np.full(mb, -1, np.int32)
        lookups = 0
        for b in range(mb):
            node: Optional[int] = sid
            while node is not None:
                nseq = self._seqs[node]
                lookups += 1
                if nseq.table[b] >= 0:
                    table[b] = nseq.table[b]
                    owner[b] = nseq.owner[b] if nseq.owner[b] >= 0 else node
                    break
                node = nseq.parent
        return table, owner, lookups

    # -- fleet-backed table materialization -----------------------------------

    def block_table(self, sid: int) -> jax.Array:
        """Direct block table for the attention kernel (fleet-resolved).
        Promotes the sequence first if any of its blocks are host-spilled
        (a stale cold block id must never reach the kernel)."""
        seq = self._live_seq(sid)
        if seq.cold:
            self.promote_seq(sid)
        table_r, _, lookups_r, _ = self._resolve_tenant(seq.tenant)
        self.lookup_count += self._count_lookups(seq, table_r, lookups_r)
        return jnp.asarray(table_r, jnp.int32)

    def _check_pad(self, n_sids: int, pad_to: int,
                   pad_block: int | None) -> None:
        if max(n_sids, pad_to) > n_sids and pad_block is None:
            raise ValueError(
                "padding rows need an explicit pad_block reserved via "
                "reserve_block(); a default of 0 would alias a live block"
            )
        if pad_block is not None and pad_block not in self._reserved:
            raise ValueError(
                f"pad_block {pad_block} was not reserved via reserve_block(); "
                "the decode step would scribble K/V into a live block"
            )

    def _assemble(self, sids, tables: np.ndarray, pad_to: int,
                  pad_block: int | None):
        """Stack per-tenant resolved rows into ONE (N, max_blocks) table +
        (N,) lengths and ship them in a single host→device transfer."""
        n = max(len(sids), pad_to)
        # without a reserved scratch block, -1 holes stay -1 (the legacy
        # block_table contract): rewriting them to any real block id would
        # alias it for the decode step's in-step K/V scatter
        fill = -1 if pad_block is None else pad_block
        out = np.full((n, self.cfg.max_blocks_per_seq), fill, np.int32)
        lengths = np.zeros(n, np.int32)
        for i, sid in enumerate(sids):
            seq = self._seqs[sid]
            row = tables[seq.tenant]
            out[i] = np.where(row >= 0, row, fill)
            lengths[i] = seq.length
        return jnp.asarray(out), jnp.asarray(lengths)

    def batched_tables(self, sids, *, pad_to: int = 0,
                       pad_block: int | None = None):
        """Fleet table materialization: ONE stacked fleet resolve covers
        every sequence, and one stacked (N, max_blocks) table + (N,)
        lengths ship to the device.

        The per-sid ``block_table`` path costs one host→device transfer
        per sequence per step; at fleet batch sizes that dominates the
        decode step. Rows beyond ``len(sids)`` (up to ``pad_to``) are
        filled with ``pad_block`` and length 0 so callers can keep a
        fixed batch shape across steps (no re-jit when the active set
        changes).

        ``pad_block`` MUST be a block taken out of circulation via
        ``reserve_block()``: the decode step's in-step scatter writes one
        K/V slot per row, padded rows included, and any live block used as
        filler would be silently corrupted.
        """
        self._check_pad(len(sids), pad_to, pad_block)
        for sid in sids:
            self._live_seq(sid)          # freed sequences must raise
        self._promote_cold(sids)
        tables, _, lookups = self._resolve_all()[:3]
        for sid in sids:
            seq = self._seqs[sid]
            self.lookup_count += self._count_lookups(
                seq, tables[seq.tenant], lookups[seq.tenant])
        return self._assemble(sids, tables, pad_to, pad_block)

    def reserve_block(self) -> int:
        """Permanently take one pool block out of circulation (e.g. as a
        scratch target for padded batch rows). Returns the block id.
        Reserved blocks are excluded from ``blocks_in_use`` — they hold no
        sequence data."""
        b = self._pop_free()
        self._reserved.add(b)
        return b

    # -- writes ----------------------------------------------------------------

    def _pop_free(self) -> int:
        if not self._free:
            raise RuntimeError("KV pool exhausted")
        b = self._free.pop()
        self._ref[b] = 1
        return b

    def _alloc(self, seq: _Seq) -> int:
        b = self._pop_free()
        seq.refs.add(b)
        return b

    def _patch(self, tables: np.ndarray, owners: np.ndarray, seq: _Seq,
               blk: int, nb: int, row_map: dict | None,
               col_map: dict | None = None) -> None:
        """Mirror one stamp into the host copy of the resolve maps, so
        later sequences in the same batch observe it exactly as the
        sequential host path did (first-hit: a layer wins iff no layer
        above it in that tenant's stack owns the page). ``row_map`` maps
        tenant ids to rows of ``tables``/``owners`` (None: identity over
        the full fleet); tenants outside the map have no host row in
        this call and their device stamp alone suffices. ``col_map``
        likewise maps logical block indexes to columns (None: identity)
        — the fused step's narrow resolve carries only the batch's write
        columns, not all ``max_blocks_per_seq`` of them."""
        def row(t: int):
            return t if row_map is None else row_map.get(t)

        col = blk if col_map is None else col_map[blk]
        if self.scalable:
            r = row(seq.tenant)
            if r is not None:
                tables[r, col] = nb
                owners[r, col] = seq.sid
            return
        for t, layer in self._occupants[seq.sid]:
            r = row(t)
            if r is not None and owners[r, col] <= layer:
                tables[r, col] = nb
                owners[r, col] = layer

    def _copy_blocks(self, src: list[int], dst: list[int]) -> None:
        """Batched COW data movement with *sequential* semantics.

        A fused gather/scatter reads every source before any write, which
        matches running the copies one by one in list order — except when
        a copy's source is a block an **earlier copy in the batch wrote**
        (a descendant COW-ing its ancestor's same-step block) and must see
        the post-copy content, or was **freed-and-recycled as an earlier
        destination** in this very batch. Flushing the batch exactly at
        each such read-after-write point keeps the result bit-identical
        to the seed's one-copy-per-prepare_write path."""
        group_s: list[int] = []
        group_d: list[int] = []

        def flush():
            if not group_s:
                return
            s = jnp.asarray(group_s, jnp.int32)
            d = jnp.asarray(group_d, jnp.int32)
            self.pool_k = self.pool_k.at[:, d].set(self.pool_k[:, s])
            self.pool_v = self.pool_v.at[:, d].set(self.pool_v[:, s])
            group_s.clear()
            group_d.clear()

        for s, d in zip(src, dst):
            if s in group_d:          # reads a block this batch writes
                flush()
            group_s.append(s)
            group_d.append(d)
        flush()

    def _prepare_block(self, seq: _Seq, blk: int, tables: np.ndarray,
                       owners: np.ndarray, row_map: dict | None,
                       writes: list, cow_src: list, cow_dst: list, *,
                       col_map: dict | None = None,
                       copy_data: bool = True) -> None:
        """The COW-prepare protocol for ONE (sequence, block) site: fresh
        alloc / COW with refcount release / owned no-op, plus the stamp
        bookkeeping and host-map patch. ``copy_data=False`` skips queueing
        the data copy of a COW (bulk prefill of a fully-covered block
        overwrites every visible slot anyway). ``row_map``/``col_map``:
        as in ``_patch``. The single place the alloc/COW/refcount
        invariants live — shared by ``prepare_step``,
        ``prepare_step_fused``, ``prepare_write`` and ``append_prefill``."""
        row = seq.tenant if row_map is None else row_map[seq.tenant]
        col = blk if col_map is None else col_map[blk]
        cur = int(tables[row, col])
        owns = seq.table[blk] >= 0 and seq.owner[blk] in (-1, seq.sid)
        if cur < 0:
            nb = self._alloc(seq)
        elif not owns:
            # COW: the block belongs to an ancestor — copy before write
            nb = self._alloc(seq)
            if copy_data:
                cow_src.append(cur)
                cow_dst.append(nb)
            if cur in seq.refs:
                seq.refs.discard(cur)
                self._ref[cur] -= 1
                if self._ref[cur] <= 0:
                    self._free.append(cur)
                    self._ref[cur] = 0
        else:
            nb = int(seq.table[blk])
        if nb != cur:
            writes.append((seq.sid, blk, nb))
            self._patch(tables, owners, seq, blk, nb, row_map, col_map)
        seq.table[blk] = nb
        seq.owner[blk] = seq.sid

    def _prepare_against(self, sids, tables: np.ndarray, owners: np.ndarray,
                         row_map: dict | None = None,
                         col_map: dict | None = None
                         ) -> list[tuple[int, int, int]]:
        """COW-prepare the next-token slot of every sid against the synced
        resolve maps. Mutates mirrors/refcounts, patches the maps in
        place, batches the COW data copies, and returns the stamp list
        ``[(sid, blk, new_block)]`` for ``_stamp_fleet``.
        ``row_map``/``col_map``: as in ``_patch``."""
        bs = self.cfg.block_size
        writes: list[tuple[int, int, int]] = []
        cow_src: list[int] = []
        cow_dst: list[int] = []
        for sid in sids:
            seq = self._live_seq(sid)
            if seq.golden:
                raise RuntimeError(
                    f"sequence {sid} is a registered golden prefix and is "
                    "frozen; fork it to continue decoding"
                )
            blk = seq.length // bs
            if blk >= self.cfg.max_blocks_per_seq:
                raise RuntimeError(f"sequence {sid} is at max_blocks_per_seq")
            self._prepare_block(seq, blk, tables, owners, row_map,
                                writes, cow_src, cow_dst, col_map=col_map)
        self._copy_blocks(cow_src, cow_dst)
        return writes

    def _stamp_fleet(self, writes: list[tuple[int, int, int]]) -> None:
        """One batched fleet stamp for a step's COW-prepares: each write
        fans out to every tenant stack holding a copy of the writer's
        layer (``_occupants``), padded to a power-of-two batch (tenant id
        T = drop sentinel) so step shapes don't re-trace."""
        if not writes:
            return
        ts, ls, ps, w0s, w1s = [], [], [], [], []
        for sid, blk, nb in writes:
            if self.scalable:
                # bfi carries the owning sid as a diagnostic (the paper's
                # 16-bit field): sids past 2^16 wrap harmlessly — table
                # materialization reads only ptr/ALLOCATED/BFI_VALID, and
                # COW ownership decisions come from the host mirrors
                w1 = fmt.FLAG_BFI_VALID | (sid & fmt.BFI_MASK)
            else:
                w1 = 0                       # vanilla images leave word1 = 0
            for t, layer in self._occupants[sid]:
                ts.append(t)
                ls.append(layer)
                ps.append(blk)
                w0s.append(fmt.FLAG_ALLOCATED | nb)
                w1s.append(w1)
        k = 1
        while k < len(ts):
            k *= 2
        pad = k - len(ts)
        t_arr = np.asarray(ts + [self.fleet.spec.n_tenants] * pad, np.int32)
        l_arr = np.asarray(ls + [0] * pad, np.int32)
        p_arr = np.asarray(ps + [0] * pad, np.int32)
        ent = np.stack([np.asarray(w0s + [0] * pad, np.uint32),
                        np.asarray(w1s + [0] * pad, np.uint32)], axis=-1)
        self.fleet = fleet_lib.stamp_entries(self.fleet, t_arr, l_arr,
                                             p_arr, ent)

    def prepare_write(self, sid: int) -> int:
        """Make the block receiving the next token writable by ``sid``.

        COW-copies an ancestor-owned block (or allocates a fresh one) so
        an in-place K/V scatter — the jitted decode step's — can never
        touch a block shared with another sequence. Returns the pool block
        that will hold the write. Commit the token afterwards with
        ``advance``. The landing block is located through the fleet
        resolve (no host chain walk); batch callers should use
        ``prepare_step``, which amortizes ONE stacked resolve over the
        whole decode batch.
        """
        seq = self._live_seq(sid)
        if seq.cold:
            self.promote_seq(sid)
        table_r, owner_r, lookups_r, _ = self._resolve_tenant(seq.tenant)
        self.lookup_count += self._count_lookups(seq, table_r, lookups_r)
        writes = self._prepare_against([sid], table_r[None], owner_r[None],
                                       row_map={seq.tenant: 0})
        self._stamp_fleet(writes)
        return int(seq.table[seq.length // self.cfg.block_size])

    def prepare_step_single(self, sid: int, *, pad_to: int = 1,
                            pad_block: int | None = None):
        """``prepare_step`` for a batch of ONE — the admission path.

        A *narrow* (single tenant row) fleet resolve drives both the
        COW-prepare and the attention table, so decoding a lone sequence
        — golden suffix admission pushing prompt tokens through the
        decode step — costs O(C·P) instead of ``_resolve_all``'s
        fleet-wide O(T·C·P): admission latency stays flat as the fleet
        fills. Output is bit-identical to ``prepare_step([sid], ...)``.
        """
        self._check_pad(1, pad_to, pad_block)
        seq = self._live_seq(sid)
        if seq.cold:
            self.promote_seq(sid)
        table_r, owner_r, lookups_r, _ = self._resolve_tenant(seq.tenant)
        self.lookup_count += self._count_lookups(seq, table_r, lookups_r)
        writes = self._prepare_against([sid], table_r[None], owner_r[None],
                                       row_map={seq.tenant: 0})
        self._stamp_fleet(writes)
        n = max(1, pad_to)
        fill = -1 if pad_block is None else pad_block
        out = np.full((n, self.cfg.max_blocks_per_seq), fill, np.int32)
        out[0] = np.where(table_r >= 0, table_r, fill)
        lengths = np.zeros(n, np.int32)
        lengths[0] = seq.length
        return jnp.asarray(out), jnp.asarray(lengths)

    def prepare_step(self, sids, *, pad_to: int = 0,
                     pad_block: int | None = None):
        """COW-prepare + table materialization for one decode step, all
        from ONE stacked fleet resolve.

        The serving engine's per-step entry point: resolves every
        sequence's full block table in a single fleet dispatch (the
        Pallas kernel plane on lane-aligned layouts), derives each
        sequence's COW-prepare decision from the synced result (no
        per-sequence host walk), stamps the prepared slots back into the
        fleet in one batched write, and returns the *post-prepare*
        ``(tables, lengths)`` — padded exactly like ``batched_tables`` —
        shipped in one transfer. ``advance`` each sid after the decode
        step commits its token.
        """
        self._check_pad(len(sids), pad_to, pad_block)
        self._promote_cold(sids)
        tables, owners, lookups, _ = self._resolve_all()
        for sid in sids:
            seq = self._live_seq(sid)
            self.lookup_count += self._count_lookups(
                seq, tables[seq.tenant], lookups[seq.tenant])
        writes = self._prepare_against(sids, tables, owners)
        self._stamp_fleet(writes)
        return self._assemble(sids, tables, pad_to, pad_block)

    def prepare_step_fused(self, sids, *, pad_to: int = 0,
                           pad_block: int | None = None) -> FusedStepPlan:
        """COW-prepare for one decode step *without* materializing block
        tables — the fused-attention counterpart of ``prepare_step``.

        The attention tables never exist on this path: the fused kernel
        (``kernels.paged_attention.fused_chain_attention``) walks the
        stacked index on-device, so the host only needs the resolve at
        the batch's **write columns** to drive the COW-prepare protocol.
        That narrow resolve — O(T·C·K) for K distinct columns instead of
        ``_resolve_all``'s O(T·C·P) — is this path's ONE designed sync
        per decode step (it *replaces* the full-table sync, see
        docs/invariants.md). Cold blocks of involved sequences are still
        promoted first, exactly as on the tables path.

        Padded rows (up to ``pad_to``) get tenant 0 with length 0 — the
        kernel masks every position — and scatter their in-step K/V
        write into the reserved ``pad_block``. ``lookup_count`` is
        charged from the host mirrors for scalable rows and parentless
        roots (bit-identical to the tables path) and with the narrow
        resolve's actual consultations for walked forks — the fused
        path's cost model (docs/kernels.md).
        """
        self._check_pad(len(sids), pad_to, pad_block)
        self._promote_cold(sids)
        bs = self.cfg.block_size
        cols = sorted({self._live_seq(sid).length // bs for sid in sids})
        # pad the column batch to the step's batch bucket, not to the
        # distinct-column count: that count flips as sequences cross
        # block boundaries, and a shape flip would retrace the narrow
        # resolve mid-serving
        k = 1
        while k < max(len(cols), pad_to):
            k *= 2
        ids = np.zeros(k, np.int32)
        ids[:len(cols)] = cols
        grid = jnp.broadcast_to(jnp.asarray(ids)[None],
                                (self.fleet.spec.n_tenants, k))
        # the fused path's ONE designed sync per step: the narrow
        # write-column resolve REPLACES _resolve_all's full-table sync
        # (docs/invariants.md) — the COW-prepare protocol needs it host-side
        out = np.array(_fleet_tables(self.fleet, grid,  # fleetlint: disable=FL002
                                     self.resolver))
        tables, owners, lookups = out[0], out[1], out[2]
        col_map = {c: i for i, c in enumerate(cols)}
        for sid in sids:
            seq = self._seqs[sid]
            if self.scalable or seq.parent is None:
                # the host mirror IS the resolved table here — identical
                # accounting to the tables path's _count_lookups
                self.lookup_count += int(np.sum(seq.table >= 0)) or 1
            else:
                self.lookup_count += int(
                    lookups[seq.tenant, col_map[seq.length // bs]])
        writes = self._prepare_against(sids, tables, owners,
                                       col_map=col_map)
        self._stamp_fleet(writes)
        n = max(len(sids), pad_to)
        tenants = np.zeros(n, np.int32)
        lengths = np.zeros(n, np.int32)
        wblocks = np.full(n, pad_block if pad_block is not None else 0,
                          np.int32)
        for i, sid in enumerate(sids):
            seq = self._seqs[sid]
            tenants[i] = seq.tenant
            lengths[i] = seq.length
            wblocks[i] = seq.table[seq.length // bs]
        return FusedStepPlan(
            l2=self.fleet.l2,
            chain_lengths=self.fleet.length,
            tenants=jnp.asarray(tenants),
            lengths=jnp.asarray(lengths),
            write_blocks=jnp.asarray(wblocks),
        )

    def commit_pools(self, pool_k: jax.Array, pool_v: jax.Array) -> None:
        """Adopt the KV pools returned by an external decode step's
        in-place scatter. The cache owns ``pool_k``/``pool_v`` (FL004);
        callers holding the functionally-updated arrays hand them back
        here instead of reaching into the cache's state."""
        if pool_k.shape != self.pool_k.shape or pool_v.shape != self.pool_v.shape:
            raise ValueError(
                f"commit_pools: shape mismatch {pool_k.shape}/{pool_v.shape} "
                f"vs cache pools {self.pool_k.shape}")
        self.pool_k = pool_k
        self.pool_v = pool_v

    def advance(self, sid: int) -> None:
        """Commit one token written externally into a slot set up by
        ``prepare_write``/``prepare_step`` (e.g. by the decode step's
        in-step scatter)."""
        seq = self._live_seq(sid)
        blk_idx = seq.length // self.cfg.block_size
        if seq.table[blk_idx] < 0 or seq.owner[blk_idx] != sid:
            raise RuntimeError(
                f"sequence {sid} has no prepared slot at position "
                f"{seq.length}; call prepare_write(sid) before advance(sid)"
            )
        seq.length += 1

    def append(self, sid: int, k: jax.Array, v: jax.Array) -> None:
        """Append one token's K/V. k, v: (L, n_kv_heads, head_dim)."""
        seq = self._live_seq(sid)
        off = seq.length % self.cfg.block_size
        nb = self.prepare_write(sid)
        self.pool_k = self.pool_k.at[:, nb, off].set(k.astype(self.cfg.dtype))
        self.pool_v = self.pool_v.at[:, nb, off].set(v.astype(self.cfg.dtype))
        self.advance(sid)

    def append_prefill(self, sid: int, k: jax.Array, v: jax.Array) -> None:
        """Bulk append. k, v: (L, T, n_kv_heads, head_dim).

        One fleet resolve + one batched stamp + one pool scatter for the
        whole prompt, instead of a per-token python loop: blocks fully
        covered by the span are allocated fresh without a COW data copy
        (their prior content would be overwritten slot by slot anyway);
        only a shared first block with a live partial prefix pays the
        copy. Block ids and refcounts come out identical to the
        token-loop path.
        """
        seq = self._live_seq(sid)
        if seq.golden:
            raise RuntimeError(
                f"sequence {sid} is a registered golden prefix and is "
                "frozen; fork it to continue decoding"
            )
        nt = int(k.shape[1])
        if nt == 0:
            return
        bs = self.cfg.block_size
        start, end = seq.length, seq.length + nt
        if (end - 1) // bs >= self.cfg.max_blocks_per_seq:
            raise RuntimeError(f"sequence {sid} is at max_blocks_per_seq")
        if seq.cold:
            self.promote_seq(sid)
        table_r, owner_r, lookups_r, _ = self._resolve_tenant(seq.tenant)
        self.lookup_count += self._count_lookups(seq, table_r, lookups_r)
        tables, owners = table_r[None], owner_r[None]
        row_map = {seq.tenant: 0}
        writes: list[tuple[int, int, int]] = []
        cow_src: list[int] = []
        cow_dst: list[int] = []
        for blk in range(start // bs, (end - 1) // bs + 1):
            # only a shared first block with a live partial prefix needs
            # its data carried over; fully-covered blocks are overwritten
            self._prepare_block(
                seq, blk, tables, owners, row_map,
                writes, cow_src, cow_dst,
                copy_data=blk == start // bs and bool(start % bs),
            )
        self._copy_blocks(cow_src, cow_dst)
        self._stamp_fleet(writes)
        pos = np.arange(start, end)
        blks = jnp.asarray(seq.table[pos // bs], jnp.int32)
        offs = jnp.asarray(pos % bs, jnp.int32)
        self.pool_k = self.pool_k.at[:, blks, offs].set(
            k.astype(self.cfg.dtype)
        )
        self.pool_v = self.pool_v.at[:, blks, offs].set(
            v.astype(self.cfg.dtype)
        )
        seq.length = end

    def prepare_span(self, sid: int, n: int):
        """COW-prepare the next ``n`` token slots of one sequence for an
        external bulk write (``serve.paged_decode.paged_suffix_prefill``).

        The prepare phase of ``append_prefill`` without the data: one
        host-side resolve, the per-block COW protocol (only a shared
        partial first block pays a data copy), one batched stamp. The
        resolve is the retained host oracle, not a fleet dispatch — this
        is the single-sequence admission edge, where a device roundtrip
        per admitted request would dominate the fork it prepares; the
        oracle's walk is O(blocks · fork depth) python over the host
        mirrors, bit-identical to the fleet resolve by the oracle
        contract. Returns ``(table, blocks, offsets)`` — the sequence's
        post-prepare resolved table (``(max_blocks,)`` int32, -1 holes)
        and the pool slot of each of the ``n`` positions. Commit with
        ``advance_span`` after the external scatter lands.
        """
        seq = self._live_seq(sid)
        if seq.golden:
            raise RuntimeError(
                f"sequence {sid} is a registered golden prefix and is "
                "frozen; fork it to continue decoding"
            )
        if n <= 0:
            raise ValueError(f"prepare_span needs n >= 1, got {n}")
        bs = self.cfg.block_size
        start, end = seq.length, seq.length + n
        if (end - 1) // bs >= self.cfg.max_blocks_per_seq:
            raise RuntimeError(f"sequence {sid} is at max_blocks_per_seq")
        if seq.cold:
            self.promote_seq(sid)
        table_r, owner_r, lookups = self._resolve_oracle(sid)
        self.lookup_count += lookups
        # the oracle may return the live host mirrors themselves — copy so
        # the patched view (and the returned table) never alias cache state
        table_r = np.array(table_r, dtype=np.int32)
        owner_r = np.array(owner_r, dtype=np.int32)
        tables, owners = table_r[None], owner_r[None]
        row_map = {seq.tenant: 0}
        writes: list[tuple[int, int, int]] = []
        cow_src: list[int] = []
        cow_dst: list[int] = []
        for blk in range(start // bs, (end - 1) // bs + 1):
            self._prepare_block(
                seq, blk, tables, owners, row_map,
                writes, cow_src, cow_dst,
                copy_data=blk == start // bs and bool(start % bs),
            )
        self._copy_blocks(cow_src, cow_dst)
        self._stamp_fleet(writes)
        pos = np.arange(start, end)
        return (table_r, seq.table[pos // bs].astype(np.int32),
                (pos % bs).astype(np.int32))

    def advance_span(self, sid: int, n: int) -> None:
        """Commit ``n`` tokens written externally into slots set up by
        ``prepare_span`` (the suffix-prefill scatter)."""
        seq = self._live_seq(sid)
        bs = self.cfg.block_size
        for p in range(seq.length, seq.length + n):
            blk = p // bs
            if seq.table[blk] < 0 or seq.owner[blk] != sid:
                raise RuntimeError(
                    f"sequence {sid} has no prepared slot at position {p}; "
                    f"call prepare_span(sid, n) before advance_span"
                )
        seq.length += n

    # -- tiering: host spill of parked sequences' exclusive blocks -------------

    def _promote_cold(self, sids) -> None:
        """Lazy promotion hook: un-spill every involved sequence *before*
        the table-producing fleet resolve (promotion mutates the fleet,
        so it must not run against an already-synced result)."""
        for sid in sids:
            if self._seqs[sid].cold:
                self.promote_seq(sid)

    def _demotable_blocks(self, seq: _Seq) -> list[int]:
        """Logical block indexes of ``seq`` that may spill to host.

        A block is demotable only when this sequence is provably its sole
        reader: the entry sits in the sequence's own layer (``owner`` is
        self), the pool block is refcounted exactly once *by this
        sequence*, no other tenant stack holds a copy of any of this
        node's layers (vanilla post-fork writes are stamped into
        descendants' stacks without a refcount, so the refcount alone
        cannot prove exclusivity), and it is not the active tail block
        still receiving tokens — the COW-layer analogue of the fleet
        rule that only immutable snapshot layers demote.
        """
        if any(t != seq.tenant for t, _ in self._occupants[seq.sid]):
            return []
        active = seq.length // self.cfg.block_size
        out = []
        for blk in range(self.cfg.max_blocks_per_seq):
            b = int(seq.table[blk])
            if (b >= 0 and blk != active and blk not in seq.cold
                    and seq.owner[blk] in (-1, seq.sid)
                    and b in seq.refs and int(self._ref[b]) == 1):
                out.append(blk)
        return out

    def _stamp_cold(self, seq: _Seq, blks: list[int]) -> None:
        """Mark ``seq``'s entries for ``blks`` host-resident: rewrite each
        with ``FLAG_COLD`` set, keeping the (now stale) block id in the
        ptr field as a breadcrumb. ``_demotable_blocks`` guarantees every
        copy of the layer lives in the sequence's own tenant stack."""
        if self.scalable:
            w1 = fmt.FLAG_BFI_VALID | (seq.sid & fmt.BFI_MASK)
        else:
            w1 = 0
        ts, ls, ps, w0s = [], [], [], []
        for t, layer in self._occupants[seq.sid]:
            for blk in blks:
                ts.append(t)
                ls.append(layer)
                ps.append(blk)
                w0s.append(fmt.FLAG_ALLOCATED | fmt.FLAG_COLD
                           | int(seq.table[blk]))
        k = 1
        while k < len(ts):
            k *= 2
        pad = k - len(ts)
        t_arr = np.asarray(ts + [self.fleet.spec.n_tenants] * pad, np.int32)
        l_arr = np.asarray(ls + [0] * pad, np.int32)
        p_arr = np.asarray(ps + [0] * pad, np.int32)
        ent = np.stack([np.asarray(w0s + [0] * pad, np.uint32),
                        np.asarray([w1] * len(ts) + [0] * pad, np.uint32)],
                       axis=-1)
        self.fleet = fleet_lib.stamp_entries(self.fleet, t_arr, l_arr,
                                             p_arr, ent)

    def demote_seq(self, sid: int, *, max_blocks: int | None = None,
                   verify: bool = True) -> int:
        """Spill a parked sequence's exclusively-owned blocks to host.

        Moves the K/V data of every demotable block (``_demotable_blocks``)
        out of ``pool_k``/``pool_v`` in one batched device→host transfer,
        returns the pool blocks to the free list, and stamps the owning
        fleet entries with ``FLAG_COLD`` so the stacked resolve reports
        the positions host-resident. ``verify`` re-reads the device copy
        before the blocks are released and requires it bit-identical to
        the staged host bytes. The sequence stays live throughout: any
        later table-producing call promotes it transparently. Returns
        the number of blocks spilled.
        """
        seq = self._live_seq(sid)
        if seq.golden:
            # a golden base's blocks back live forks bit-for-bit; spilling
            # them would stale the shared table ids under the forks
            return 0
        blks = self._demotable_blocks(seq)
        if max_blocks is not None:
            blks = blks[:max_blocks]
        if not blks:
            return 0
        bids = [int(seq.table[blk]) for blk in blks]
        sel = jnp.asarray(bids, jnp.int32)
        ks = np.asarray(self.pool_k[:, sel])
        vs = np.asarray(self.pool_v[:, sel])
        if verify:
            k2 = np.asarray(self.pool_k[:, sel])
            v2 = np.asarray(self.pool_v[:, sel])
            if (ks.view(np.uint8) != k2.view(np.uint8)).any() or (
                    vs.view(np.uint8) != v2.view(np.uint8)).any():
                raise RuntimeError(
                    f"demote_seq({sid}): device read not stable")
        host = self._cold_kv.setdefault(sid, {})
        for i, blk in enumerate(blks):
            host[blk] = (ks[:, i], vs[:, i])
            seq.cold.add(blk)
        self._stamp_cold(seq, blks)
        for b in bids:
            seq.refs.discard(b)
            self._ref[b] = 0
            self._free.append(b)
        self.demoted_blocks += len(blks)
        return len(blks)

    def promote_seq(self, sid: int) -> int:
        """Un-spill every host-resident block of a sequence.

        Allocates fresh pool blocks, restores the K/V data in one batched
        host→device scatter, bit-verifies the landed bytes against the
        host copy, and stamps the entries hot again through the normal
        write protocol (which clears ``FLAG_COLD``). This is what a
        resumed deep fork pays, lazily, on the first decode step it
        actually joins. Returns the number of blocks promoted.
        """
        seq = self._live_seq(sid)
        if not seq.cold:
            return 0
        blks = sorted(seq.cold)
        host = self._cold_kv[sid]
        nbs = [self._alloc(seq) for _ in blks]
        sel = jnp.asarray(nbs, jnp.int32)
        ks = np.stack([host[blk][0] for blk in blks], axis=1)
        vs = np.stack([host[blk][1] for blk in blks], axis=1)
        self.pool_k = self.pool_k.at[:, sel].set(
            jnp.asarray(ks, self.cfg.dtype))
        self.pool_v = self.pool_v.at[:, sel].set(
            jnp.asarray(vs, self.cfg.dtype))
        # bit-verify readback on the (rare) promote-on-resume edge — the
        # docs/memory.md residency contract, not a per-step cost
        back_k = np.asarray(self.pool_k[:, sel])  # fleetlint: disable=FL002
        back_v = np.asarray(self.pool_v[:, sel])  # fleetlint: disable=FL002
        if (ks.view(np.uint8) != back_k.view(np.uint8)).any() or (
                vs.view(np.uint8) != back_v.view(np.uint8)).any():
            raise RuntimeError(
                f"promote_seq({sid}): host→device transfer corrupted data")
        writes = []
        for blk, nb in zip(blks, nbs):
            seq.table[blk] = nb
            host.pop(blk)
            writes.append((seq.sid, blk, nb))
        seq.cold.clear()
        if not host:
            self._cold_kv.pop(sid, None)
        self._stamp_fleet(writes)
        self.promoted_blocks += len(blks)
        return len(blks)

    def host_blocks_in_use(self) -> int:
        """Blocks currently resident in the host tier (spilled K/V)."""
        return sum(len(d) for d in self._cold_kv.values())

    # -- golden prefixes: content-addressed shared-base registration -----------

    def register_golden(self, sid: int) -> str:
        """Freeze a sequence as a golden shared-prefix base.

        Promotes any spilled blocks first (a base must stay fully
        device-resident — its table ids back every fork bit-for-bit),
        then computes the content address: a sha256 over the sequence's
        *resolved* K/V bytes and length, so two prefixes hash equal
        exactly when their cached state is bit-identical, regardless of
        fork topology or block placement. A registered base is frozen:
        every write path and ``free_seq`` refuse it, and ``demote_seq``
        skips it, until ``release_golden``. Forking it stays the normal
        ``fork`` — O(1) table clone + refcounts. Idempotent for an
        already-registered sid. Returns the content hash.
        """
        seq = self._live_seq(sid)
        if sid in self._golden:
            return self._golden[sid]
        if seq.length == 0:
            raise ValueError(f"sequence {sid} is empty; nothing to register")
        if seq.cold:
            self.promote_seq(sid)
        k, v = self.gather(sid)
        h = hashlib.sha256()
        h.update(np.asarray([seq.length], np.int64).tobytes())
        h.update(np.ascontiguousarray(np.asarray(k)).tobytes())
        h.update(np.ascontiguousarray(np.asarray(v)).tobytes())
        digest = h.hexdigest()
        seq.golden = True
        self._golden[sid] = digest
        return digest

    def release_golden(self, sid: int) -> str:
        """Un-freeze a golden base, returning its content hash. The
        sequence becomes an ordinary live sequence again (writable,
        freeable, demotable); forks taken while it was golden keep their
        shared blocks alive through the usual refcounts."""
        if sid not in self._golden:
            raise KeyError(f"sequence {sid} is not a registered golden prefix")
        digest = self._golden.pop(sid)
        self._seqs[sid].golden = False
        return digest

    def is_golden(self, sid: int) -> bool:
        return sid in self._golden

    def golden_stats(self) -> dict:
        """Dedup accounting of the registered golden bases.

        ``golden_blocks``: distinct pool blocks referenced by golden
        sequences. ``golden_blocks_shared``: the subset whose refcount
        exceeds one — blocks live forks are aliasing right now.
        ``dedup_blocks_saved``: sum over golden blocks of ``ref - 1`` —
        the pool blocks a dedup-free serving plane would additionally
        hold to back the same set of sequences.
        """
        blocks: set[int] = set()
        for sid in self._golden:
            blocks |= self._seqs[sid].refs
        shared = sum(1 for b in blocks if int(self._ref[b]) > 1)
        saved = sum(int(self._ref[b]) - 1 for b in blocks)
        return dict(
            golden_seqs=len(self._golden),
            golden_blocks=len(blocks),
            golden_blocks_shared=shared,
            dedup_blocks_saved=saved,
        )

    # -- reads (reference path; kernels/paged_attention is the fast path) ------

    def gather(self, sid: int):
        """Materialize (L, T, H, D) K/V for a sequence (test oracle)."""
        seq = self._live_seq(sid)
        table, _, _ = self._resolve_oracle(sid)
        bs = self.cfg.block_size
        n_blk = -(-seq.length // bs) if seq.length else 0
        ks, vs = [], []
        cold = self._cold_kv.get(sid, {})
        for b in range(n_blk):
            if b in seq.cold:
                # spilled blocks read straight from the host tier — the
                # oracle must not perturb residency by promoting
                ks.append(jnp.asarray(cold[b][0], self.cfg.dtype))
                vs.append(jnp.asarray(cold[b][1], self.cfg.dtype))
            else:
                ks.append(self.pool_k[:, table[b]])
                vs.append(self.pool_v[:, table[b]])
        if not ks:
            L, H, D = self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim
            return (jnp.zeros((L, 0, H, D), self.cfg.dtype),) * 2
        k = jnp.concatenate(ks, axis=1)[:, :seq.length]
        v = jnp.concatenate(vs, axis=1)[:, :seq.length]
        return k, v

    def seq_length(self, sid: int) -> int:
        return self._seqs[sid].length

    # -- live migration: move a sequence's KV state between caches -------------

    def seq_fingerprint(self, sid: int) -> str:
        """Digest of everything about a live sequence that a decode step,
        append, spill or promotion could change — the mid-flight guard
        for ``export_seq``. A fork of the sequence does *not* change it
        (COW: the parent's data is untouched), so forks landing during a
        migration are harmless."""
        seq = self._live_seq(sid)
        h = hashlib.sha256()
        h.update(np.asarray([seq.length], np.int64).tobytes())
        h.update(np.ascontiguousarray(seq.table).tobytes())
        h.update(np.ascontiguousarray(seq.owner).tobytes())
        h.update(np.asarray(sorted(seq.cold), np.int64).tobytes())
        return h.hexdigest()

    def export_seq(self, sid: int) -> dict:
        """Pack a live sequence into a portable, self-contained blob.

        The K/V payload is *resolved* — read back through the fork chain
        and the host tier — so the blob depends on no other sequence:
        ancestors, tombstones and spilled blocks all stay behind on the
        source. Pure read (residency is not perturbed; spilled blocks are
        served from the host tier, not promoted).
        """
        cfg = self.cfg
        k, v = self.gather(sid)
        return dict(
            n_layers=cfg.n_layers,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            dtype=np.dtype(cfg.dtype).name,
            length=self._live_seq(sid).length,
            k=np.asarray(k),
            v=np.asarray(v),
            fingerprint=self.seq_fingerprint(sid),
        )

    def import_seq(self, blob: dict) -> int:
        """Land an exported sequence in this cache as a fresh root.

        The migrated sequence arrives with no parent — its resolved
        prefix is bulk-appended (``append_prefill``), so its blocks are
        exclusively owned here and the source-side fork topology does not
        follow it. Block size, pool size and format flag may all differ
        from the source cache; the model geometry must match.
        """
        cfg = self.cfg
        for field in ("n_layers", "n_kv_heads", "head_dim"):
            if blob[field] != getattr(cfg, field):
                raise ValueError(
                    f"imported sequence disagrees on {field}: blob has "
                    f"{blob[field]}, cache has {getattr(cfg, field)}"
                )
        if np.dtype(blob["dtype"]) != np.dtype(cfg.dtype):
            raise ValueError(
                f"imported sequence dtype {blob['dtype']} != cache dtype "
                f"{np.dtype(cfg.dtype).name}"
            )
        if blob["length"] > cfg.max_blocks_per_seq * cfg.block_size:
            raise ValueError(
                f"imported sequence length {blob['length']} exceeds this "
                "cache's max_blocks_per_seq"
            )
        sid = self.new_seq()
        if blob["length"]:
            self.append_prefill(sid, jnp.asarray(blob["k"]),
                                jnp.asarray(blob["v"]))
        return sid

    def blocks_in_use(self) -> int:
        """Blocks holding sequence data (reserved scratch blocks excluded)."""
        return int(np.sum(self._ref > 0)) - len(self._reserved)
