"""Paged KV cache with COW sequence forking — the serving-side integration.

vLLM-style block pool, plus the paper's two designs at the block-table
level:

* **vanilla fork** (vQemu analogue): a forked sequence starts with an empty
  block table and a parent pointer; resolving block *b* walks the fork
  chain until an ancestor that owns it is found — O(fork depth) per block.
* **scalable fork** (sQEMU analogue): fork copies the parent's *resolved*
  table forward, with an ``owner`` id per block (the ``backing_file_index``
  analogue) — O(1) per block, and the attention kernel receives a direct
  block table (``kernels/paged_attention``).

COW: appending to a block owned by an ancestor first copies it into a
fresh pool block (cluster copy-on-write). Pool blocks are refcounted so
shared prefixes are stored once (paper Fig 7: base-image sharing).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    block_size: int = 16
    n_blocks: int = 256
    max_blocks_per_seq: int = 64
    dtype: object = jnp.bfloat16


@dataclasses.dataclass
class _Seq:
    sid: int
    table: np.ndarray        # (max_blocks,) int32 pool block or -1
    owner: np.ndarray        # (max_blocks,) int32 owning sid (bfi analogue)
    parent: Optional[int]
    length: int
    refs: set = dataclasses.field(default_factory=set)  # blocks we refcount
    freed: bool = False      # tombstone: freed but pinned by live children


class PagedKVCache:
    def __init__(self, cfg: PagedKVConfig, *, scalable: bool = True):
        self.cfg = cfg
        self.scalable = scalable
        shape = (cfg.n_layers, cfg.n_blocks, cfg.block_size,
                 cfg.n_kv_heads, cfg.head_dim)
        self.pool_k = jnp.zeros(shape, cfg.dtype)
        self.pool_v = jnp.zeros(shape, cfg.dtype)
        self._free = list(range(cfg.n_blocks - 1, -1, -1))
        self._ref = np.zeros(cfg.n_blocks, np.int32)
        self._reserved: set[int] = set()
        self._seqs: dict[int, _Seq] = {}
        self._next_sid = 0
        self.lookup_count = 0  # fork-chain index consultations (Fig 13 analogue)

    # -- sequence lifecycle ---------------------------------------------------

    def new_seq(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        mb = self.cfg.max_blocks_per_seq
        self._seqs[sid] = _Seq(
            sid, np.full(mb, -1, np.int32), np.full(mb, -1, np.int32), None, 0
        )
        return sid

    def fork(self, sid: int) -> int:
        parent = self._live_seq(sid)
        child = self._next_sid
        self._next_sid += 1
        mb = self.cfg.max_blocks_per_seq
        shared, _, _ = self._resolve(sid)
        if self.scalable:
            # sQEMU snapshot copy-forward: the child's table directly indexes
            # every ancestor-owned block (owner = the bfi analogue).
            owner = np.where(shared >= 0, parent.owner, -1)
            owner = np.where((shared >= 0) & (owner < 0), sid, owner)
            seq = _Seq(child, shared.copy(), owner, None, parent.length)
        else:
            seq = _Seq(child, np.full(mb, -1, np.int32),
                       np.full(mb, -1, np.int32), sid, parent.length)
        # the child holds a reference on every shared block
        seq.refs = {int(b) for b in shared[shared >= 0]}
        for b in seq.refs:
            self._ref[b] += 1
        self._seqs[child] = seq
        return child

    def free_seq(self, sid: int) -> None:
        """Free a sequence, tombstoning it while forked children live.

        A vanilla-forked child resolves missing blocks by walking its
        ``parent`` chain, so a parent cannot simply vanish while children
        exist: the walk would ``KeyError`` and the child would lose every
        ancestor-owned block. Freeing such a parent leaves a *tombstone* —
        the node and its block refs stay until the last descendant is
        freed, then the whole dead suffix of the chain is reaped at once.
        """
        seq = self._live_seq(sid)
        seq.freed = True
        self._reap(seq)

    def _live_seq(self, sid: int) -> _Seq:
        seq = self._seqs[sid]
        if seq.freed:
            raise KeyError(f"sequence {sid} has been freed")
        return seq

    def _reap(self, seq: _Seq) -> None:
        # Release freed nodes bottom-up: a node goes only when *nothing*
        # (live or tombstoned) still names it as parent; its removal may
        # in turn orphan a tombstoned ancestor, so walk up the chain.
        while (seq is not None and seq.freed
               and not any(s.parent == seq.sid for s in self._seqs.values())):
            for b in seq.refs:
                self._ref[b] -= 1
                if self._ref[b] <= 0:
                    self._free.append(int(b))
                    self._ref[b] = 0
            del self._seqs[seq.sid]
            seq = (self._seqs.get(seq.parent)
                   if seq.parent is not None else None)

    # -- resolution: vanilla walk vs direct ------------------------------------

    def _resolve(self, sid: int):
        """Flattened (table, owner, lookups) for a sequence."""
        seq = self._seqs[sid]
        if self.scalable or seq.parent is None:
            lookups = int(np.sum(seq.table >= 0)) or 1
            self.lookup_count += lookups
            return seq.table, seq.owner, lookups
        # vanilla: per block, walk up the fork chain
        mb = self.cfg.max_blocks_per_seq
        table = np.full(mb, -1, np.int32)
        owner = np.full(mb, -1, np.int32)
        lookups = 0
        for b in range(mb):
            node: Optional[int] = sid
            while node is not None:
                nseq = self._seqs[node]
                lookups += 1
                if nseq.table[b] >= 0:
                    table[b] = nseq.table[b]
                    owner[b] = nseq.owner[b] if nseq.owner[b] >= 0 else node
                    break
                node = nseq.parent
        self.lookup_count += lookups
        return table, owner, lookups

    def block_table(self, sid: int) -> jax.Array:
        """Direct block table for the attention kernel."""
        table, _, _ = self._resolve(sid)
        return jnp.asarray(table, jnp.int32)

    def batched_tables(self, sids, *, pad_to: int = 0,
                       pad_block: int | None = None):
        """Fleet-style table materialization: resolve every sequence and ship
        ONE stacked (N, max_blocks) table + (N,) lengths to the device.

        The per-sid ``block_table`` path costs one host→device transfer per
        sequence per step; at fleet batch sizes that dominates the decode
        step. Rows beyond ``len(sids)`` (up to ``pad_to``) are filled with
        ``pad_block`` and length 0 so callers can keep a fixed batch shape
        across steps (no re-jit when the active set changes).

        ``pad_block`` MUST be a block taken out of circulation via
        ``reserve_block()``: the decode step's in-step scatter writes one
        K/V slot per row, padded rows included, and any live block used as
        filler would be silently corrupted.
        """
        n = max(len(sids), pad_to)
        if n > len(sids) and pad_block is None:
            raise ValueError(
                "padding rows need an explicit pad_block reserved via "
                "reserve_block(); a default of 0 would alias a live block"
            )
        if pad_block is not None and pad_block not in self._reserved:
            raise ValueError(
                f"pad_block {pad_block} was not reserved via reserve_block(); "
                "the decode step would scribble K/V into a live block"
            )
        # without a reserved scratch block, -1 holes stay -1 (the legacy
        # block_table contract): rewriting them to any real block id would
        # alias it for the decode step's in-step K/V scatter
        fill = -1 if pad_block is None else pad_block
        tables = np.full((n, self.cfg.max_blocks_per_seq), fill, np.int32)
        lengths = np.zeros(n, np.int32)
        for i, sid in enumerate(sids):
            table, _, _ = self._resolve(sid)
            tables[i] = np.where(table >= 0, table, fill)
            lengths[i] = self._seqs[sid].length
        return jnp.asarray(tables), jnp.asarray(lengths)

    def reserve_block(self) -> int:
        """Permanently take one pool block out of circulation (e.g. as a
        scratch target for padded batch rows). Returns the block id.
        Reserved blocks are excluded from ``blocks_in_use`` — they hold no
        sequence data."""
        b = self._pop_free()
        self._reserved.add(b)
        return b

    # -- writes ----------------------------------------------------------------

    def _pop_free(self) -> int:
        if not self._free:
            raise RuntimeError("KV pool exhausted")
        b = self._free.pop()
        self._ref[b] = 1
        return b

    def _alloc(self, seq: _Seq) -> int:
        b = self._pop_free()
        seq.refs.add(b)
        return b

    def prepare_write(self, sid: int) -> int:
        """Make the block receiving the next token writable by ``sid``.

        COW-copies an ancestor-owned block (or allocates a fresh one) so
        an in-place K/V scatter — the jitted decode step's — can never
        touch a block shared with another sequence. Returns the pool block
        that will hold the write. Commit the token afterwards with
        ``advance``. This is the public contract the serving engine uses;
        it must not reach into ``_seqs`` and mutate the refcount/ownership
        invariants by hand.
        """
        seq = self._live_seq(sid)
        blk_idx = seq.length // self.cfg.block_size
        if blk_idx >= self.cfg.max_blocks_per_seq:
            raise RuntimeError(f"sequence {sid} is at max_blocks_per_seq")
        resolved, _, _ = self._resolve(sid)
        cur = int(resolved[blk_idx])
        owns = seq.table[blk_idx] >= 0 and seq.owner[blk_idx] in (-1, sid)
        if cur < 0:
            nb = self._alloc(seq)
        elif not owns:
            # COW: the block belongs to an ancestor — copy before write
            nb = self._alloc(seq)
            self.pool_k = self.pool_k.at[:, nb].set(self.pool_k[:, cur])
            self.pool_v = self.pool_v.at[:, nb].set(self.pool_v[:, cur])
            if cur in seq.refs:
                seq.refs.discard(cur)
                self._ref[cur] -= 1
                if self._ref[cur] <= 0:
                    self._free.append(cur)
                    self._ref[cur] = 0
        else:
            nb = int(seq.table[blk_idx])
        seq.table[blk_idx] = nb
        seq.owner[blk_idx] = sid
        return nb

    def advance(self, sid: int) -> None:
        """Commit one token written externally into a slot set up by
        ``prepare_write`` (e.g. by the decode step's in-step scatter)."""
        seq = self._live_seq(sid)
        blk_idx = seq.length // self.cfg.block_size
        if seq.table[blk_idx] < 0 or seq.owner[blk_idx] != sid:
            raise RuntimeError(
                f"sequence {sid} has no prepared slot at position "
                f"{seq.length}; call prepare_write(sid) before advance(sid)"
            )
        seq.length += 1

    def append(self, sid: int, k: jax.Array, v: jax.Array) -> None:
        """Append one token's K/V. k, v: (L, n_kv_heads, head_dim)."""
        seq = self._live_seq(sid)
        off = seq.length % self.cfg.block_size
        nb = self.prepare_write(sid)
        self.pool_k = self.pool_k.at[:, nb, off].set(k.astype(self.cfg.dtype))
        self.pool_v = self.pool_v.at[:, nb, off].set(v.astype(self.cfg.dtype))
        self.advance(sid)

    def append_prefill(self, sid: int, k: jax.Array, v: jax.Array) -> None:
        """Bulk append. k, v: (L, T, n_kv_heads, head_dim)."""
        for t in range(k.shape[1]):
            self.append(sid, k[:, t], v[:, t])

    # -- reads (reference path; kernels/paged_attention is the fast path) ------

    def gather(self, sid: int):
        """Materialize (L, T, H, D) K/V for a sequence (test oracle)."""
        seq = self._seqs[sid]
        table, _, _ = self._resolve(sid)
        bs = self.cfg.block_size
        n_blk = -(-seq.length // bs) if seq.length else 0
        ks, vs = [], []
        for b in range(n_blk):
            ks.append(self.pool_k[:, table[b]])
            vs.append(self.pool_v[:, table[b]])
        if not ks:
            L, H, D = self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim
            return (jnp.zeros((L, 0, H, D), self.cfg.dtype),) * 2
        k = jnp.concatenate(ks, axis=1)[:, :seq.length]
        v = jnp.concatenate(vs, axis=1)[:, :seq.length]
        return k, v

    def seq_length(self, sid: int) -> int:
        return self._seqs[sid].length

    def blocks_in_use(self) -> int:
        """Blocks holding sequence data (reserved scratch blocks excluded)."""
        return int(np.sum(self._ref > 0)) - len(self._reserved)
