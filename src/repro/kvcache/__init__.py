"""kvcache subsystem."""
