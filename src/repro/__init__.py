"""repro: SnapStore — snapshot-chain state management for JAX at scale."""
