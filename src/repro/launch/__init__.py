"""launch subsystem."""
