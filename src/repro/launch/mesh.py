"""Production meshes (TPU v5e pods) — functions only, no import-time jax
device-state side effects."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over real host devices (tests)."""
    n = len(jax.devices())
    data = min(data, max(1, n // model)) if n >= model else 1
    model = min(model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


# TPU v5e hardware constants (per chip) for the roofline terms.
HW = dict(
    peak_flops_bf16=197e12,   # FLOP/s
    hbm_bw=819e9,             # B/s
    ici_bw_per_link=50e9,     # B/s per link (~)
)
