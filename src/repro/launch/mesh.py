"""Production meshes (TPU v5e pods) — functions only, no import-time jax
device-state side effects."""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions: ``axis_types`` (and the
    ``AxisType`` enum itself) only exist on newer releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across JAX versions: 0.4.x takes one
    ``((name, size), ...)`` tuple; newer releases take (shape, names)."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over real host devices (tests)."""
    n = len(jax.devices())
    data = min(data, max(1, n // model)) if n >= model else 1
    model = min(model, n)
    return _mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline terms.
HW = dict(
    peak_flops_bf16=197e12,   # FLOP/s
    hbm_bw=819e9,             # B/s
    ici_bw_per_link=50e9,     # B/s per link (~)
)
