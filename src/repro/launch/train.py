"""Distributed training launcher.

On real hardware this process runs per host with jax.distributed; here it
runs the same code path over the local device mesh. The production mesh
geometry is selected with --production (requires 256/512 devices, i.e. the
dry-run's fake-device mode); --host uses whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 20 --scale smoke
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--scale", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--production", action="store_true",
                    help="use the 16x16 production mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.scale == "full" else smoke_config(args.arch)
    model = get_model(cfg)
    mesh = (make_production_mesh() if args.production else make_host_mesh())
    rules = sh.make_rules(mesh)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params)")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every)
    with mesh, sh.use_rules(rules):
        trainer = Trainer(model, AdamWConfig(lr=1e-3, total_steps=args.steps),
                          dcfg, tcfg)
        report = trainer.run()
    print(f"done: loss {trainer.losses[0]:.3f} -> {trainer.losses[-1]:.3f}  "
          f"goodput={report['goodput']:.2f}  "
          f"ckpt chain={report['ckpt_chain_length']}  "
          f"stragglers={report['straggler_steps']}")


if __name__ == "__main__":
    main()
