import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first backend init (see MULTI-POD DRY-RUN spec).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with the compiled
memory analysis, cost analysis (FLOPs / bytes), per-device collective
bytes (``hlo_analysis``), and derived roofline terms.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells_for, get_config, list_archs
from repro.distributed import sharding as sh
from repro.launch import hlo_analysis
from repro.launch.mesh import HW, make_production_mesh
from repro.models import get_model
from repro.models.api import batch_specs
from repro.optim import adamw
from repro.train.train_step import make_train_step

# Gradient-accumulation plan for the big train cells (keeps per-device
# activation memory within a v5e's 16 GB HBM; see EXPERIMENTS.md §Dry-run).
ACCUM = {
    ("qwen2-72b", "train_4k"): 16,
    ("chameleon-34b", "train_4k"): 8,
    ("nemotron-4-15b", "train_4k"): 8,
    ("qwen2-7b", "train_4k"): 8,
    ("phi3.5-moe-42b-a6.6b", "train_4k"): 8,
    ("qwen2.5-3b", "train_4k"): 4,
    ("qwen2-moe-a2.7b", "train_4k"): 4,
    ("zamba2-2.7b", "train_4k"): 4,
    ("rwkv6-3b", "train_4k"): 4,
    ("whisper-base", "train_4k"): 2,
}


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    return batch_specs(cfg, spec.global_batch, spec.seq_len, kind=spec.kind)


def _n_dp(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def _tuned_config(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    groups = _n_dp(mesh)
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    while groups > 1 and tokens % groups:
        groups //= 2
    return dataclasses.replace(cfg, dispatch_groups=groups)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               compile_only: bool = False, extra: dict | None = None,
               variant: dict | None = None) -> dict:
    """``variant``: perf-iteration knobs — ``seq_shard`` (bool, SP),
    ``cast_bf16`` (bool, pre-gather cast), ``accum`` (int override)."""
    variant = variant or {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = sh.make_rules(mesh, seq_shard=bool(variant.get("seq_shard")))
    cfg = _tuned_config(arch, shape_name, mesh)
    if extra:
        cfg = dataclasses.replace(cfg, **extra)
    model = get_model(cfg)
    spec = SHAPES[shape_name]
    n_dev = len(mesh.devices.reshape(-1))

    params_shapes = model.init_shapes()
    if variant.get("params_bf16"):
        # serving-standard bf16 weights: halves weight-gather wire bytes
        # and weight HBM reads (stored dtype, not a foldable cast)
        params_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 and len(s.shape) >= 2 else s,
            params_shapes,
        )
    p_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        sh.param_specs(params_shapes, rules),
        is_leaf=lambda x: isinstance(x, P),
    )
    b_specs = input_specs(arch, shape_name)
    b_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        sh.batch_spec(b_specs, rules),
        is_leaf=lambda x: isinstance(x, P),
    )

    t0 = time.time()
    with mesh, sh.use_rules(rules):
        if spec.kind == "train":
            accum = int(variant.get("accum", ACCUM.get((arch, shape_name), 1)))
            opt_shapes = jax.eval_shape(adamw.init, params_shapes)
            o_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                sh.param_specs(opt_shapes, rules),
                is_leaf=lambda x: isinstance(x, P),
            )
            step_fn = make_train_step(
                model, adamw.AdamWConfig(), accum_steps=accum,
                cast_bf16=bool(variant.get("cast_bf16")),
                grad_shardings=None if variant.get("no_grad_pin") else p_shard,
            )
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            ).lower(params_shapes, opt_shapes, b_specs)
        elif spec.kind == "prefill":
            lowered = jax.jit(
                model.prefill, in_shardings=(p_shard, b_shard)
            ).lower(params_shapes, b_specs)
        else:  # decode — serve_step: one token against a seq_len KV cache
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(spec.global_batch, spec.seq_len)
            )
            c_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                sh.cache_specs(cache_shapes, rules),
                is_leaf=lambda x: isinstance(x, P),
            )
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(p_shard, c_shard, b_shard["tokens"]),
                donate_argnums=(1,),
            ).lower(params_shapes, cache_shapes, b_specs["tokens"])
        compiled = lowered.compile()
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    wc = hlo_analysis.weighted_costs(hlo)

    # trip-count-weighted (cost_analysis counts while bodies once)
    flops_dev = float(wc["flops"])
    bytes_dev = float(wc["hbm_bytes"])
    # ring all-reduce moves ~2x the payload over a link; others ~1x
    coll_dev = float(coll["total"]) + float(coll["all-reduce"])

    # model FLOPs (the "useful work" yardstick)
    n_active = cfg.active_param_count()
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    if spec.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens

    terms = dict(
        compute_s=flops_dev / HW["peak_flops_bf16"],
        memory_s=bytes_dev / HW["hbm_bw"],
        collective_s=coll_dev / HW["ici_bw_per_link"],
    )
    bottleneck = max(terms, key=terms.get)

    rec = dict(
        arch=arch,
        shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=n_dev,
        kind=spec.kind,
        accum=ACCUM.get((arch, shape_name), 1) if spec.kind == "train" else 1,
        compile_s=round(compile_s, 1),
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            peak_bytes_per_device=(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        ),
        flops_per_device=flops_dev,
        hbm_bytes_per_device=bytes_dev,
        xla_cost_analysis=dict(
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        ),
        collective_bytes_per_device={k: v for k, v in coll.items()},
        model_flops_total=model_flops,
        model_flops_per_device=model_flops / n_dev,
        useful_flops_ratio=(model_flops / n_dev) / flops_dev if flops_dev else 0.0,
        roofline_terms_s=terms,
        bottleneck=bottleneck,
        roofline_frac=(
            (model_flops / n_dev / HW["peak_flops_bf16"]) / max(terms.values())
            if max(terms.values()) > 0 else 0.0
        ),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in list_archs():
            for shape in cells_for(arch):
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        mesh_tag = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_tag}.json")
        if os.path.exists(path):
            print(f"[skip] {arch} {shape} {mesh_tag} (exists)")
            continue
        print(f"[lower+compile] {arch} {shape} {mesh_tag} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, multi_pod=mp)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"  ok: compile={rec['compile_s']}s "
                f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB/dev "
                f"flops/dev={rec['flops_per_device']:.3g} "
                f"coll/dev={rec['collective_bytes_per_device']['total']:.3g}B "
                f"bottleneck={rec['bottleneck']}",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 — a cell failure is a bug report
            failures += 1
            print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
            with open(path + ".fail", "w") as f:
                f.write(traceback.format_exc())
    print(f"done. failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
