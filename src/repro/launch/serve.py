"""Serving launcher: continuous batching over the COW paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --requests 4 --forks 2 --tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import get_model
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--scale", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--forks", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--vanilla", action="store_true",
                    help="vanilla fork chains (walks) instead of direct")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.scale == "full" else smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, scalable=not args.vanilla, n_blocks=1024,
                 block_size=8, max_blocks_per_seq=64)

    rng = np.random.default_rng(0)
    roots = []
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, args.prompt_len)
        roots.append(eng.add_request(prompt))
    for r in roots:
        for _ in range(args.forks):
            eng.fork_request(r)

    t0 = time.perf_counter()
    for _ in range(args.tokens):
        eng.step()
    dt = time.perf_counter() - t0
    st = eng.memory_stats()
    n_seqs = st["n_seqs"]
    print(f"{n_seqs} sequences ({args.requests} roots x {args.forks} forks), "
          f"{args.tokens} steps in {dt:.2f}s "
          f"({n_seqs*args.tokens/dt:.1f} tok/s)")
    print(f"blocks in use: {st['blocks_in_use']} "
          f"(independent copies would need ~"
          f"{n_seqs * (args.prompt_len // 8 + 2)}); "
          f"table lookups: {st['lookups']} "
          f"({'vanilla walk' if args.vanilla else 'direct'})")


if __name__ == "__main__":
    main()
