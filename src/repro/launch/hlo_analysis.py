"""Post-SPMD HLO analysis: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but no collective
accounting, so we parse ``compiled.as_text()`` (the per-device, post-
partitioning module): every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op contributes
its payload bytes, multiplied by the trip count of every enclosing while
loop (scan-over-layers puts the per-layer collectives inside a while body
that appears once in the text but runs n_layers times).

Trip counts are recovered heuristically from the while condition
computation (the largest integer literal compared against the induction
variable) — exact for lax.scan/fori_loop lowerings.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of every typed shape literal in ``shape_text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    name = None
    depth = 0
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)(?:\s*\([^)]*\))?.*\{")
    for line in hlo.splitlines():
        stripped = line.strip()
        if name is None:
            m = header.match(stripped)
            if m and stripped.endswith("{"):
                name = m.group(1)
                comps[name] = []
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            name = None
            continue
        comps[name].append(stripped)
    return {k: "\n".join(v) for k, v in comps.items()}


def _trip_count(cond_text: str) -> int:
    consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_text)]
    return max(consts) if consts else 1


def _multipliers(comps: dict[str, str]) -> dict[str, int]:
    """Execution multiplier per computation (product of enclosing trips)."""
    while_re = re.compile(
        r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)"
    )
    call_re = re.compile(
        r"(?:fusion|call|custom-call|conditional)\(.*?\).*?"
        r"(?:calls|to_apply)=%?([\w.\-]+)"
    )
    mult: dict[str, int] = defaultdict(lambda: 0)
    entry = None
    for cname in comps:
        if "main" in cname or entry is None:
            entry = entry or cname
        if "main" in cname:
            entry = cname
    mult[entry] = 1
    # simple fixed-point propagation over the call graph
    for _ in range(64):
        changed = False
        for cname, text in comps.items():
            m = mult[cname]
            if m == 0:
                continue
            for wm in while_re.finditer(text):
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, ""))
                for target in (body, cond):
                    newm = m * max(trips, 1)
                    if newm > mult[target]:
                        mult[target] = newm
                        changed = True
            for cm in call_re.finditer(text):
                target = cm.group(1)
                if target in comps and m > mult[target]:
                    mult[target] = m
                    changed = True
        if not changed:
            break
    return dict(mult)


_SKIP_BYTES_OPS = (
    "parameter", "constant", "get-tuple-element", "tuple(", "bitcast",
    "after-all", "custom-call(",
)


_LINE_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(shape_text: str):
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return None, []
    dims = [int(x) for x in m.group(2).split(",") if x] if m.group(2) else []
    return m.group(1), dims


def weighted_costs(hlo: str) -> dict[str, float]:
    """Trip-count-weighted per-device FLOPs and HBM bytes.

    XLA's ``cost_analysis()`` counts each HLO op once, so a scanned layer
    stack (while loop) is undercounted by its trip count. We re-derive:

    * ``flops``: 2·prod(result)·K for every ``dot`` (K = product of the
      lhs contracting dims, resolved through a per-computation symbol
      table since optimized HLO operands are bare names), × the multiplier
      of the enclosing loops;
    * ``hbm_bytes``: result+operand bytes of every top-level op in
      non-fusion computations (fusion internals don't touch HBM), × the
      multiplier. Matches cost_analysis' per-op convention, loop-weighted.
    """
    comps = _split_computations(hlo)
    mult = _multipliers(comps)
    fusion_bodies: set[str] = set()
    fusion_re = re.compile(
        r"(?:fusion|custom-call)\(.*?\).*?(?:calls|to_apply)=%?([\w.\-]+)"
    )
    for text in comps.values():
        for fm in fusion_re.finditer(text):
            fusion_bodies.add(fm.group(1))

    flops = 0.0
    hbm = 0.0
    for cname, text in comps.items():
        m = mult.get(cname, 1) or 1
        in_fusion = cname in fusion_bodies
        # symbol table: instruction name -> result shape text
        shapes: dict[str, str] = {}
        parsed = []
        for line in text.splitlines():
            lm = _LINE_RE.match(line)
            if not lm:
                continue
            shapes[lm.group(1)] = lm.group(2)
            parsed.append((lm.group(1), lm.group(2), lm.group(3), line))
        for name, rshape, opname, line in parsed:
            if opname == "dot":
                _, rdims = _shape_dims(rshape)
                after = line.split(" dot(", 1)[1]
                ops = _OPERAND_RE.findall(after.split(")", 1)[0])
                cdims = _CONTRACT_RE.search(line)
                k = 1.0
                if ops and cdims is not None:
                    _, lhs_dims = _shape_dims(shapes.get(ops[0], ""))
                    for ci in (cdims.group(1).split(",") if cdims.group(1)
                               else []):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                n_elems = 1.0
                for d in rdims:
                    n_elems *= d
                flops += 2.0 * n_elems * k * m
            if in_fusion:
                continue
            if opname in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "after-all", "iota"):
                continue
            after = line.split("(", 1)[1] if "(" in line else ""
            operands = _OPERAND_RE.findall(after.split(")", 1)[0])
            if opname == "dynamic-update-slice":
                # XLA performs DUS in place (buffer aliasing): the traffic
                # is the update slice, not the whole buffer. Counting the
                # full KV cache per scan trip would overstate decode
                # memory by ~2 orders of magnitude.
                upd = _shape_bytes(shapes.get(operands[1], "")) if len(
                    operands) > 1 else 0
                hbm += 2 * upd * m
                continue
            if opname == "dynamic-slice":
                # reads only the slice, not the sliced-from buffer
                hbm += 2 * _shape_bytes(rshape) * m
                continue
            rbytes = _shape_bytes(rshape)
            obytes = [_shape_bytes(shapes.get(op, "")) for op in operands]
            if opname == "fusion":
                # XLA loop fusions around (dynamic-)slice/update ops alias
                # their big operand: an update fusion writes only the
                # update (count the small operands twice); a slice-read
                # fusion reads only O(result). Without this, a scanned KV
                # cache counts its full buffer once per layer.
                if ("update_slice" in line or "scatter" in line) and any(
                        o == rbytes for o in obytes):
                    hbm += 2 * sum(o for o in obytes if o != rbytes) * m
                    continue
                if "dynamic_slice" in line or "gather" in line:
                    hbm += (rbytes + sum(o for o in obytes
                                         if o <= 16 * rbytes)) * m
                    continue
            nbytes = rbytes + sum(obytes)
            hbm += nbytes * m
    return dict(flops=flops, hbm_bytes=hbm)


def collective_bytes(hlo: str) -> dict[str, float]:
    """Per-device payload bytes by collective kind (trip-count weighted)."""
    comps = _split_computations(hlo)
    mult = _multipliers(comps)
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+(" + "|".join(_COLLECTIVES) +
        r")(?:-start|-done)?\("
    )
    for cname, text in comps.items():
        m = mult.get(cname, 1) or 1
        for line in text.splitlines():
            om = op_re.search(line)
            if not om:
                continue
            result_text, kind = om.group(1), om.group(2)
            if "-done(" in line:
                continue  # avoid double-counting async start/done pairs
            nbytes = _shape_bytes(result_text)
            if kind == "reduce-scatter":
                # payload is the (larger) operand
                operand = line[om.end():]
                nbytes = max(nbytes, _shape_bytes(operand))
            out[kind] += float(nbytes) * m
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out
