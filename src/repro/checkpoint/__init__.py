"""checkpoint subsystem."""
