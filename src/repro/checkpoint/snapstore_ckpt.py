"""Incremental (delta) checkpointing of training state on the snapshot store.

Every ``save`` writes only the *dirty pages* of the flattened training
state into the chain's active volume and then snapshots — a COW backing
file per checkpoint, exactly the paper's workload (§3: daily-or-faster
snapshot creation, chains into the hundreds). ``restore`` materializes the
virtual disk through either resolver:

* ``method="vanilla"`` — the O(chain) walk (vQemu restore);
* ``method="direct"``  — sQEMU direct access, O(1) per page;
* ``method="pallas_vanilla"``/``"pallas_direct"`` — the same strategies
  through the stacked Pallas kernels (``docs/kernels.md``), viewing the
  checkpoint chain as a one-tenant fleet.

Fig 17's "VM boot time" maps to cold ``restore`` latency (benchmarks/
fig17_boot.py). The provider's streaming policy (merge beyond a threshold,
default 30 — §3 Take-away 2) is ``maybe_stream``.

Durability: ``save_to_dir``/``load_from_dir`` round-trip the whole chain
through ``.npz`` so a restarted process can resume (trainer restart path).
Fleet tenants get the same durability via the migration blob
(``save_tenant_to_dir``/``load_tenant_from_dir`` — a checkpoint of one
tenant IS a migration into a directory; ``docs/migration.md``).
Elastic restore: ``restore`` returns replicated host values; pass
``shardings`` to place them for a *different* mesh than they were saved
from (tested by tests/test_checkpoint.py::test_elastic_reshard).
"""

from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain as chain_lib
from repro.core import resolve as resolve_lib
from repro.core import store as store_lib
from repro.core.chain import Chain, ChainSpec


def _leaf_to_u32(leaf: jax.Array) -> jax.Array:
    if leaf.dtype == jnp.uint32:
        return leaf.reshape(-1)
    if leaf.dtype in (jnp.float32, jnp.int32):
        return jax.lax.bitcast_convert_type(leaf, jnp.uint32).reshape(-1)
    if leaf.dtype in (jnp.bfloat16, jnp.float16):
        pad = leaf.size % 2
        flat = leaf.reshape(-1)
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((1,), leaf.dtype)])
        return jax.lax.bitcast_convert_type(
            flat.reshape(-1, 2), jnp.uint32
        ).reshape(-1)
    raise TypeError(f"unsupported checkpoint dtype {leaf.dtype}")


def _u32_to_leaf(words: jax.Array, shape, dtype) -> jax.Array:
    size = int(np.prod(shape)) if shape else 1
    if dtype == jnp.uint32:
        return words[:size].reshape(shape)
    if dtype in (jnp.float32, jnp.int32):
        return jax.lax.bitcast_convert_type(words[:size], dtype).reshape(shape)
    if dtype in (jnp.bfloat16, jnp.float16):
        n_words = -(-size // 2)
        halves = jax.lax.bitcast_convert_type(words[:n_words], dtype)
        return halves.reshape(-1)[:size].reshape(shape)
    raise TypeError(f"unsupported checkpoint dtype {dtype}")


def _words_per_leaf(leaf) -> int:
    if leaf.dtype in (jnp.bfloat16, jnp.float16):
        return -(-leaf.size // 2)
    return leaf.size


class SnapshotCheckpointer:
    """COW delta-checkpoint chain for an arbitrary training-state pytree."""

    def __init__(
        self,
        template: Any,
        *,
        page_size: int = 2048,
        max_chain: int = 64,
        scalable: bool = True,
        stream_threshold: int = 30,
        pool_slack: float = 4.0,
    ):
        self.template = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), template
        )
        leaves = jax.tree.leaves(self.template)
        self._offsets = np.cumsum([0] + [_words_per_leaf(l) for l in leaves])
        total_words = int(self._offsets[-1])
        n_pages = max(1, -(-total_words // page_size))
        self.spec = ChainSpec(
            n_pages=_round_up(n_pages, 64),
            page_size=page_size,
            max_chain=max_chain,
            pool_capacity=int(_round_up(n_pages, 64) * pool_slack),
            dtype=jnp.uint32,
        )
        self.chain: Chain = chain_lib.create(self.spec, scalable=scalable)
        self.stream_threshold = stream_threshold
        self._shadow: Optional[jax.Array] = None  # last-saved page image
        self.stats: list[dict] = []

    # -- flatten / unflatten -------------------------------------------------

    def _flatten(self, state) -> jax.Array:
        words = jnp.concatenate(
            [_leaf_to_u32(l) for l in jax.tree.leaves(state)]
        )
        total = self.spec.n_pages * self.spec.page_size
        words = jnp.pad(words, (0, total - words.shape[0]))
        return words.reshape(self.spec.n_pages, self.spec.page_size)

    def _unflatten(self, pages: jax.Array):
        words = pages.reshape(-1)
        leaves_t = jax.tree.leaves(self.template)
        leaves = []
        for i, lt in enumerate(leaves_t):
            seg = words[int(self._offsets[i]):int(self._offsets[i + 1])]
            leaves.append(_u32_to_leaf(seg, lt.shape, lt.dtype))
        return jax.tree.unflatten(jax.tree.structure(self.template), leaves)

    # -- save / restore -------------------------------------------------------

    def save(self, state) -> dict:
        """Write dirty pages + snapshot. Returns per-save stats."""
        pages = self._flatten(state)
        if self._shadow is None:
            dirty = np.ones((self.spec.n_pages,), bool)
        else:
            dirty = np.asarray(
                jnp.any(pages != self._shadow, axis=1)
            )
        ids = np.nonzero(dirty)[0].astype(np.int32)
        if ids.size:
            if int(self.chain.pool_cursor) + ids.size > self.spec.pool_capacity:
                # background GC: stream old deltas, then compact the pool
                if int(self.chain.length) > 3:
                    self.chain = store_lib.stream(
                        self.chain, int(self.chain.length) - 3,
                        copy_data=False)
                self.chain = chain_lib.compact_pool(self.chain)
            self.chain = store_lib.write(
                self.chain, jnp.asarray(ids), pages[jnp.asarray(ids)]
            )
        self.chain = store_lib.snapshot(self.chain)
        # guard after the snapshot so a drop (chain at max_chain) surfaces
        # on THIS save, before the next save overwrites the active volume
        store_lib.check_pool_capacity(self.chain)
        self._shadow = pages
        st = dict(
            pages_written=int(ids.size),
            bytes_written=int(ids.size) * self.spec.page_size * 4,
            chain_length=int(self.chain.length),
        )
        self.stats.append(st)
        self.maybe_stream()
        return st

    def save_async(self, state):
        """Non-blocking save: snapshots device state immediately (cheap
        reference under JAX's functional arrays) and runs the dirty-page
        diff + write on a worker thread. Returns a Future with the stats.

        The training loop continues while the delta is written — the
        standard async-checkpoint overlap. Saves are serialized by a lock
        (chain updates are ordered)."""
        import concurrent.futures as _fut

        if not hasattr(self, "_pool"):
            self._pool = _fut.ThreadPoolExecutor(max_workers=1)
            self._lock = __import__("threading").Lock()

        def job():
            with self._lock:
                return self.save(state)

        return self._pool.submit(job)

    def restore(self, *, method: str = "direct", shardings: Any = None):
        pages = store_lib.materialize(self.chain, method=method)
        state = self._unflatten(pages)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state

    def resolve_cost(self, method: str) -> int:
        """Total index lookups a full restore performs (Fig 17 low-level)."""
        ids = jnp.arange(self.spec.n_pages, dtype=jnp.int32)
        res = resolve_lib.get_resolver(method)(self.chain, ids)
        return int(jnp.sum(res.lookups))

    # -- maintenance -----------------------------------------------------------

    def maybe_stream(self) -> bool:
        """Provider streaming policy: compact when the chain passes the
        threshold (keeps the most recent ``stream_threshold // 2`` deltas)."""
        if int(self.chain.length) <= self.stream_threshold:
            return False
        keep = max(2, self.stream_threshold // 2)
        merge_upto = int(self.chain.length) - keep - 1
        self.chain = store_lib.stream(self.chain, merge_upto, copy_data=False)
        return True

    # -- durability ------------------------------------------------------------

    def save_to_dir(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez_compressed(
            os.path.join(path, "chain.npz"),
            l1=np.asarray(self.chain.l1),
            l2=np.asarray(self.chain.l2),
            pool=np.asarray(self.chain.pool),
            pool_cursor=np.asarray(self.chain.pool_cursor),
            length=np.asarray(self.chain.length),
            overflow=np.asarray(self.chain.overflow),
            snap_dropped=np.asarray(self.chain.snap_dropped),
            shadow=np.asarray(self._shadow) if self._shadow is not None else np.zeros(0),
        )

    def load_from_dir(self, path: str) -> None:
        z = np.load(os.path.join(path, "chain.npz"))
        import dataclasses as dc

        self.chain = dc.replace(
            self.chain,
            l1=jnp.asarray(z["l1"]),
            l2=jnp.asarray(z["l2"]),
            pool=jnp.asarray(z["pool"]),
            pool_cursor=jnp.asarray(z["pool_cursor"]),
            length=jnp.asarray(z["length"]),
            overflow=jnp.asarray(z["overflow"]),
            snap_dropped=(jnp.asarray(z["snap_dropped"])
                          if "snap_dropped" in z.files
                          else jnp.zeros((), bool)),
        )
        self._shadow = jnp.asarray(z["shadow"]) if z["shadow"].size else None


def save_tenant_to_dir(fleet, t: int, path: str, *, store=None) -> None:
    """Durable per-tenant checkpoint: export tenant ``t`` as a migration
    blob and write it under ``path``.

    A tenant checkpoint and a migration share one container — the
    pointer-localized ``TenantBlob`` (``core.migrate``) — so a blob
    saved here can be restored into *any* fleet whose logical geometry
    matches, not just a recreation of the one it came from. ``store`` is
    required when the tenant holds cold (host-tier) layers.
    """
    from repro.core import migrate as migrate_lib

    os.makedirs(path, exist_ok=True)
    blob = migrate_lib.export_tenant(fleet, t, store=store)
    migrate_lib.save_blob(blob, os.path.join(path, f"tenant_{t}.npz"))


def load_tenant_from_dir(fleet, t: int, path: str, *, src_tenant=None,
                         store=None):
    """Restore a tenant checkpoint into slot ``t`` of ``fleet``.

    ``src_tenant`` names the slot the blob was saved from (defaults to
    ``t``); the destination slot is evicted and the blob lands through
    the fleet's own lease allocator. Returns the updated fleet.
    """
    from repro.core import migrate as migrate_lib

    src = t if src_tenant is None else src_tenant
    blob = migrate_lib.load_blob(os.path.join(path, f"tenant_{src}.npz"))
    return migrate_lib.import_tenant(fleet, t, blob, store=store)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
