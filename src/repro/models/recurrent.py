"""Chunked, remat-friendly time scans for recurrent families (RWKV6, Mamba2).

The TPU-native formulation: all projections (big MXU matmuls) are computed
for the whole sequence *outside* the recurrence; the scan body carries only
the small recurrent state. The time axis is processed in chunks — the outer
scan saves one carry per chunk (remat boundary), the inner scan runs the
per-step recurrence — so backward memory is O(S / chunk * state) instead of
O(S * state).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def chunked_time_scan(step_fn, carry, xs, *, chunk: int = 64, remat: bool = True):
    """scan ``step_fn`` over the time axis (axis 0 of each leaf of ``xs``).

    step_fn: (carry, x_t) -> (carry, y_t). Returns (carry, ys) with ys
    stacked over time, like ``lax.scan``.
    """
    length = jax.tree.leaves(xs)[0].shape[0]
    if length <= chunk:
        return jax.lax.scan(step_fn, carry, xs)

    n_chunks = -(-length // chunk)
    pad = n_chunks * chunk - length

    def pad_leaf(leaf):
        cfgpad = [(0, pad)] + [(0, 0)] * (leaf.ndim - 1)
        leaf = jnp.pad(leaf, cfgpad)
        return leaf.reshape((n_chunks, chunk) + leaf.shape[1:])

    xs_c = jax.tree.map(pad_leaf, xs)

    def chunk_body(carry, x_chunk):
        return jax.lax.scan(step_fn, carry, x_chunk)

    if remat:
        chunk_body = jax.checkpoint(chunk_body)
    carry, ys_c = jax.lax.scan(chunk_body, carry, xs_c)

    def unpad_leaf(leaf):
        leaf = leaf.reshape((n_chunks * chunk,) + leaf.shape[2:])
        return leaf[:length]

    return carry, jax.tree.map(unpad_leaf, ys_c)


def causal_depthwise_conv(x, w, b, *, prev=None):
    """Causal depthwise 1-D conv over time. x: (B, S, C); w: (K, C).

    ``prev``: (B, K-1, C) carried context for streaming decode (None →
    zero history). Returns (out (B, S, C), new_prev).
    """
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)            # (B, S+K-1, C)
    out = jnp.zeros_like(x)
    for i in range(k):                                  # K is tiny (4)
        out = out + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    out = out + b.astype(x.dtype)
    new_prev = xp[:, -(k - 1):] if k > 1 else prev
    return out, new_prev


def token_shift(x, prev):
    """RWKV token shift: x_{t-1} along time. x: (B, S, d); prev: (B, d)."""
    shifted = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return shifted, x[:, -1]
