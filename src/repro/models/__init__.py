"""Model zoo: dense/MoE transformers, enc-dec, RWKV6, Mamba2 hybrid."""

from repro.models.api import LM, batch_specs, get_model, make_batch  # noqa: F401
