"""Shared neural-net layers: norms, RoPE, attention, MLPs, embeddings.

Everything is a pure function over explicit parameter pytrees (dicts of
arrays). Layer stacks are *stacked* along a leading axis and driven with
``jax.lax.scan`` + ``jax.checkpoint`` so that 80-layer models lower to a
single rolled loop (small HLO, fast compiles, remat-friendly).

Compute dtype is bf16 (TPU MXU native); parameters and softmax/loss
accumulation are f32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, *, scale=None, dtype=PARAM_DTYPE):
    scale = (1.0 / jnp.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab, d_model, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d // 2], x32[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int):
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((n_pos, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# attention (pure-JAX flash-style reference; Pallas kernels override on TPU)
# ---------------------------------------------------------------------------

def _grouped_scores(q, k):
    """Grouped-query scores without materializing repeated KV.

    q: (B, Sq, H, D), k: (B, Sk, Hkv, D) with H = Hkv * G.
    Returns (B, Hkv, G, Sq, Sk) f32.
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )


def _grouped_out(probs, v):
    """probs: (B, Hkv, G, Sq, Sk) ; v: (B, Sk, Hkv, D) → (B, Sq, H, D)."""
    b, hkv, g, sq, sk = probs.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hkv * g, v.shape[-1])


def attention_ref(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                  q_chunk: int = 1024):
    """Chunked exact attention (softmax per q-chunk over full K rows).

    Memory is O(q_chunk * Sk) per chunk instead of O(Sq * Sk) — this is
    what lets 32k-token prefill lower within HBM. ``kv_len`` masks the
    valid prefix of the KV buffers (decode with a partially filled cache).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kpos = jnp.arange(sk)

    def one_chunk(q_blk, q_start):
        scores = _grouped_scores(q_blk, k) * scale           # (B,Hkv,G,qc,Sk)
        mask = jnp.ones((q_blk.shape[1], sk), bool)
        if causal:
            qpos = q_start + jnp.arange(q_blk.shape[1]) + q_offset
            mask &= kpos[None, :] <= qpos[:, None]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = jnp.where(jnp.any(mask, -1, keepdims=True), probs, 0.0)
        return _grouped_out(probs, v)

    if sq <= q_chunk:
        return one_chunk(q, 0)

    n_chunks = (sq + q_chunk - 1) // q_chunk
    pad = n_chunks * q_chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = qp.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)

    def body(_, inputs):
        q_blk, i = inputs
        return None, one_chunk(q_blk, i * q_chunk)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * q_chunk, h, d)
    return out[:, :sq]


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """Single-position attention against a (possibly oversized) KV cache.

    q: (B, 1, H, D); caches: (B, S, Hkv, D); kv_len: scalar or (B,).
    """
    return attention_ref(q, k_cache, v_cache, causal=False, kv_len=kv_len)


# ---------------------------------------------------------------------------
# attention block parameters
# ---------------------------------------------------------------------------

def attn_init(key, d_model, n_heads, n_kv_heads, head_dim, *, qkv_bias, qk_norm,
              n_layers_scale=1):
    ks = jax.random.split(key, 4)
    p = dict(
        wq=dense_init(ks[0], d_model, n_heads * head_dim),
        wk=dense_init(ks[1], d_model, n_kv_heads * head_dim),
        wv=dense_init(ks[2], d_model, n_kv_heads * head_dim),
        wo=dense_init(ks[3], n_heads * head_dim, d_model,
                      scale=1.0 / jnp.sqrt(2.0 * n_layers_scale * d_model)),
    )
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), PARAM_DTYPE)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), PARAM_DTYPE)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), PARAM_DTYPE)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), PARAM_DTYPE)
        p["k_norm"] = jnp.ones((head_dim,), PARAM_DTYPE)
    return p


def attn_qkv(p, x, n_heads, n_kv_heads, head_dim, positions, *, rope_theta,
             use_rope=True):
    """Project to rope'd q/k and v. x: (B, S, d) → (B,S,H,D),(B,S,Hkv,D)x2."""
    b, s, _ = x.shape
    cd = x.dtype
    q = x @ p["wq"].astype(cd)
    k = x @ p["wk"].astype(cd)
    v = x @ p["wv"].astype(cd)
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, *, gated: bool, n_layers_scale=1):
    ks = jax.random.split(key, 3)
    p = dict(
        w_up=dense_init(ks[0], d_model, d_ff),
        w_down=dense_init(ks[1], d_ff, d_model,
                          scale=1.0 / jnp.sqrt(2.0 * n_layers_scale * d_ff)),
    )
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def mlp_apply(p, x, activation: str):
    cd = x.dtype
    act = activation_fn(activation)
    up = x @ p["w_up"].astype(cd)
    if "w_gate" in p:
        up = act(x @ p["w_gate"].astype(cd)) * up
    else:
        up = act(up)
    return up @ p["w_down"].astype(cd)


# ---------------------------------------------------------------------------
# LM loss (chunked over sequence so (B,S,V) never fully materializes)
# ---------------------------------------------------------------------------

def lm_loss(hidden, w_out, labels, *, s_chunk: int = 512, mask=None):
    """Cross-entropy of hidden @ w_out against labels, chunked over S.

    hidden: (B, S, d) compute-dtype; w_out: (d, V); labels: (B, S) int32.
    Returns mean nll over unmasked positions (f32 scalar).
    """
    b, s, d = hidden.shape
    if mask is None:
        mask = jnp.ones((b, s), bool)
    n_chunks = max(1, (s + s_chunk - 1) // s_chunk)
    pad = n_chunks * s_chunk - s
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))
    hp = hp.reshape(b, n_chunks, s_chunk, d).transpose(1, 0, 2, 3)
    lp = lp.reshape(b, n_chunks, s_chunk).transpose(1, 0, 2)
    mp = mp.reshape(b, n_chunks, s_chunk).transpose(1, 0, 2)

    def body(acc, inp):
        h, lab, m = inp
        logits = (h @ w_out.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = jnp.where(m, lse - gold, 0.0)
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m)), None

    (total, count), _ = jax.lax.scan(body, (0.0, 0.0), (hp, lp, mp))
    return total / jnp.maximum(count, 1.0)
