"""Mixture-of-Experts feed-forward: shared + routed top-k experts.

Dispatch is sort-based with per-group capacity (no (T, E, C) one-hot —
that would never fit at 1M tokens): token→expert assignments are argsorted,
ranked within their expert segment and scattered into a dense
``(groups, E, capacity, d)`` buffer whose group axis shards over the data
axis (local dispatch per DP shard) and whose expert axis shards over the
model axis (EP). Overflowing assignments are dropped (standard
capacity-factor semantics); a load-balance aux loss keeps the router
honest.

``dispatch_groups`` must divide the token count; the launcher sets it to
the DP shard count so dispatch is shard-local (no cross-batch traffic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models import layers as L


def moe_init(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    p = dict(
        router=L.dense_init(ks[0], d, e, scale=0.02),
        e_gate=jax.vmap(lambda k: L.dense_init(k, d, f))(jax.random.split(ks[1], e)),
        e_up=jax.vmap(lambda k: L.dense_init(k, d, f))(jax.random.split(ks[2], e)),
        e_down=jax.vmap(
            lambda k: L.dense_init(k, f, d, scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers * f))
        )(jax.random.split(ks[3], e)),
    )
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.moe_d_ff
        p["shared"] = L.mlp_init(ks[4], d, fs, gated=True,
                                 n_layers_scale=cfg.n_layers)
        p["shared_gate"] = L.dense_init(ks[5], d, 1, scale=0.02)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    cap = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)


def moe_apply(cfg: ModelConfig, p, x):
    """x: (B, S, d) → (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.dispatch_groups
    t = b * s
    assert t % g == 0, f"dispatch_groups {g} must divide token count {t}"
    tg = t // g
    cap = _capacity(tg, cfg)

    xt = x.reshape(g, tg, d)
    xt = lshard(xt, "dispatch", None, "embed")

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (g,tg,e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                            # (g,tg,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): e * sum(frac_tokens * frac_prob)
    pe = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / k
    aux = e * jnp.sum(pe * fe)

    def dispatch_one(xg, ig):
        """xg: (tg, d); ig: (tg, k) → buf (e, cap, d), slot (tg*k,), ok."""
        flat_e = ig.reshape(-1)                                       # (tg*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        rank = jnp.arange(tg * k) - first
        ok = rank < cap
        slot_sorted = jnp.where(ok, sorted_e * cap + rank, e * cap)   # drop
        tok_sorted = order // k
        buf = jnp.zeros((e * cap, d), xg.dtype).at[slot_sorted].set(
            xg[tok_sorted], mode="drop"
        )
        # map back to unsorted assignment order
        slot = jnp.zeros((tg * k,), jnp.int32).at[order].set(
            slot_sorted.astype(jnp.int32)
        )
        return buf.reshape(e, cap, d), slot

    buf, slot = jax.vmap(dispatch_one)(xt, top_i)                     # (g,e,cap,d)
    buf = lshard(buf, "dispatch", "expert", None, "embed")

    cd = x.dtype
    h = jnp.einsum("gecd,edf->gecf", buf, p["e_up"].astype(cd))
    gate = jnp.einsum("gecd,edf->gecf", buf, p["e_gate"].astype(cd))
    h = jax.nn.silu(gate) * h
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["e_down"].astype(cd))
    out_buf = lshard(out_buf, "dispatch", "expert", None, "embed")

    def combine_one(ob, sl, w):
        flat = ob.reshape(e * cap, d)
        picked = jnp.where(
            (sl < e * cap)[:, None], flat[jnp.minimum(sl, e * cap - 1)], 0.0
        )                                                            # (tg*k, d)
        return jnp.sum(
            picked.reshape(tg, k, d) * w[..., None].astype(ob.dtype), axis=1
        )

    out = jax.vmap(combine_one)(out_buf, slot, top_p)                # (g,tg,d)
    out = out.reshape(b, s, d)

    if "shared" in p:
        sh = L.mlp_apply(p["shared"], x, "silu")
        sgate = jax.nn.sigmoid(
            (x @ p["shared_gate"].astype(cd)).astype(jnp.float32)
        ).astype(cd)
        out = out + sh * sgate
    return out, aux
