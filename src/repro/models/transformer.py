"""Decoder-only transformer (dense GQA + MoE variants).

Covers qwen2-72b/7b, qwen2.5-3b, nemotron-4-15b (squared-ReLU, ungated),
chameleon-34b (qk-norm, VQ-token vocab), qwen2-moe-a2.7b and
phi3.5-moe-42b-a6.6b (cfg.is_moe → routed FF via ``models.moe``).

Layer stack is scanned + rematerialized; KV caches are (L, B, S, Hkv, D)
with the sequence axis sharded over the model axis for decode (DESIGN §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models import layers as L
from repro.models import moe as moe_lib


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    k_embed, k_out, k_layers = jax.random.split(key, 3)

    def layer_init(k):
        ka, kf = jax.random.split(k)
        p = dict(
            ln1=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            ln2=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
            attn=L.attn_init(
                ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
                n_layers_scale=cfg.n_layers,
            ),
        )
        if cfg.is_moe:
            p["ff"] = moe_lib.moe_init(cfg, kf)
        else:
            p["ff"] = L.mlp_init(kf, cfg.d_model, cfg.d_ff,
                                 gated=cfg.gated_mlp, n_layers_scale=cfg.n_layers)
        return p

    params = dict(
        embed=L.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        ln_f=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        layers=jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers)),
    )
    if not cfg.tie_embeddings:
        params["w_out"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                       scale=0.02)
    return params


def output_matrix(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["w_out"]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _ff(cfg: ModelConfig, p_ff, h):
    if cfg.is_moe:
        return moe_lib.moe_apply(cfg, p_ff, h)
    return L.mlp_apply(p_ff, h, cfg.activation), jnp.float32(0.0)


def block_fwd(cfg: ModelConfig, p, x, positions):
    """Full-sequence (train / prefill) block. Returns (x, (k, v, aux))."""
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         positions, rope_theta=cfg.rope_theta,
                         use_rope=cfg.use_rope)
    q = lshard(q, "batch", "seq", "heads", "head_dim")
    k = lshard(k, "batch", "seq", "kv_heads", "head_dim")
    v = lshard(v, "batch", "seq", "kv_heads", "head_dim")
    attn = L.attention_ref(q, k, v, causal=True)
    attn = attn.reshape(x.shape[0], x.shape[1], cfg.n_heads * cfg.hd)
    x = x + attn @ p["attn"]["wo"].astype(x.dtype)
    x = lshard(x, "batch", "seq", "embed")
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    ff_out, aux = _ff(cfg, p["ff"], h2)
    x = x + ff_out
    x = lshard(x, "batch", "seq", "embed")
    # cache-destined copies are sequence-sharded (kv_seq → model axis) so a
    # 32k-token prefill's collected KV fits per-device HBM
    k_out = lshard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v_out = lshard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return x, (k_out, v_out, aux)


def block_decode(cfg: ModelConfig, p, x, k_cache, v_cache, pos):
    """One-token block. x: (B,1,d); caches (B,S,Hkv,D); pos scalar."""
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         positions, rope_theta=cfg.rope_theta,
                         use_rope=cfg.use_rope)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    k_cache = lshard(k_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    v_cache = lshard(v_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    attn = L.decode_attention_ref(q, k_cache, v_cache, pos + 1)
    attn = attn.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd).astype(x.dtype)
    x = x + attn @ p["attn"]["wo"].astype(x.dtype)
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    ff_out, _ = _ff(cfg, p["ff"], h2)
    return x + ff_out, k_cache, v_cache


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def _scan_stack(cfg: ModelConfig, layers, x, positions, *, collect_kv: bool):
    def body(carry, p):
        x, aux_acc = carry
        x, (k, v, aux) = block_fwd(cfg, p, x, positions)
        ys = (k, v) if collect_kv else None
        return (x, aux_acc + aux), ys

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), kv = jax.lax.scan(body, (x, jnp.float32(0.0)), layers)
    return x, aux, kv


def _embed(cfg: ModelConfig, params, tokens):
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    if not cfg.use_rope:
        pos = L.sinusoidal_positions(tokens.shape[1], cfg.d_model)
        x = x + pos[None].astype(x.dtype)
    return lshard(x, "batch", "seq", "embed")


def loss_fn(cfg: ModelConfig, params, tokens, labels):
    """Teacher-forced LM loss. tokens/labels: (B, S) int32."""
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]
    x, aux, _ = _scan_stack(cfg, params["layers"], x, positions,
                            collect_kv=False)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    nll = L.lm_loss(x, output_matrix(cfg, params).astype(x.dtype), labels)
    return nll + 0.01 * aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    dt = jnp.float32 if cfg.cache_f32 else L.COMPUTE_DTYPE
    return dict(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill(cfg: ModelConfig, params, tokens):
    """Returns (last-position logits (B, V), cache)."""
    b, s = tokens.shape
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    x, _, (ks, vs) = _scan_stack(cfg, params["layers"], x, positions,
                                 collect_kv=True)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ output_matrix(cfg, params).astype(x.dtype)).astype(
        jnp.float32
    )
    cache = dict(k=ks, v=vs, pos=jnp.asarray(s, jnp.int32))
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens: (B, 1). Returns (logits (B, V), updated cache)."""
    pos = cache["pos"]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    if not cfg.use_rope:
        # sinusoidal at the current position
        pe = L.sinusoidal_positions(1, cfg.d_model)  # placeholder freq row
        x = x + pe[None].astype(x.dtype)

    def body(x, inputs):
        p, kc, vc = inputs
        x, kc, vc = block_decode(cfg, p, x, kc, vc, pos)
        return x, (kc, vc)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ output_matrix(cfg, params).astype(x.dtype)).astype(
        jnp.float32
    )
    return logits, dict(k=ks, v=vs, pos=pos + 1)
