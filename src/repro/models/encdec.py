"""whisper-style encoder-decoder backbone (conv/mel frontend stubbed).

``frames`` — precomputed frame embeddings (B, F, d_model) from
``input_specs()`` — stand in for the conv1d+mel frontend, per the
assignment's [audio] stub rule. Encoder: bidirectional self-attention;
decoder: causal self-attention + cross-attention; GELU MLPs, LayerNorm,
sinusoidal positions (extended past whisper's 448 decoder positions to
honour the assigned shapes — noted in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models import layers as L


def _attn_block_init(cfg: ModelConfig, key, *, cross: bool):
    ka, kf = jax.random.split(key)
    p = dict(
        ln1=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        ln1b=jnp.zeros((cfg.d_model,), L.PARAM_DTYPE),
        ln2=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        ln2b=jnp.zeros((cfg.d_model,), L.PARAM_DTYPE),
        attn=L.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         qkv_bias=False, qk_norm=False,
                         n_layers_scale=cfg.n_layers),
        ff=L.mlp_init(kf, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                      n_layers_scale=cfg.n_layers),
    )
    if cross:
        kx = jax.random.fold_in(key, 7)
        p["lnx"] = jnp.ones((cfg.d_model,), L.PARAM_DTYPE)
        p["lnxb"] = jnp.zeros((cfg.d_model,), L.PARAM_DTYPE)
        p["xattn"] = L.attn_init(kx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, qkv_bias=False, qk_norm=False,
                                 n_layers_scale=cfg.n_layers)
    return p


def init_params(cfg: ModelConfig, key):
    k_embed, k_enc, k_dec = jax.random.split(key, 3)
    return dict(
        embed=L.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        ln_f=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        ln_fb=jnp.zeros((cfg.d_model,), L.PARAM_DTYPE),
        enc_ln=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        enc_lnb=jnp.zeros((cfg.d_model,), L.PARAM_DTYPE),
        enc_layers=jax.vmap(lambda k: _attn_block_init(cfg, k, cross=False))(
            jax.random.split(k_enc, cfg.n_enc_layers)),
        dec_layers=jax.vmap(lambda k: _attn_block_init(cfg, k, cross=True))(
            jax.random.split(k_dec, cfg.n_layers)),
    )


def _self_attn(cfg, p, x, positions, *, causal, prefix="", kv=None, kv_len=None):
    h = L.layernorm(x, p[prefix + "ln1"] if not prefix else p["lnx"],
                    p[prefix + "ln1b"] if not prefix else p["lnxb"],
                    cfg.norm_eps)
    ap = p["attn"] if not prefix else p["xattn"]
    if kv is None:
        q, k, v = L.attn_qkv(ap, h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                             positions, rope_theta=cfg.rope_theta,
                             use_rope=False)
        out = L.attention_ref(q, k, v, causal=causal, kv_len=kv_len)
    else:
        b, s, _ = h.shape
        q = (h @ ap["wq"].astype(h.dtype)).reshape(b, s, cfg.n_heads, cfg.hd)
        k, v = kv
        out = L.attention_ref(q, k, v, causal=False, kv_len=kv_len)
    out = out.reshape(x.shape[0], x.shape[1], cfg.n_heads * cfg.hd)
    return x + out @ ap["wo"].astype(x.dtype), (k, v)


def _mlp(cfg, p, x):
    h = L.layernorm(x, p["ln2"], p["ln2b"], cfg.norm_eps)
    return x + L.mlp_apply(p["ff"], h, cfg.activation)


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, F, d_model) stub embeddings → encoder memory."""
    x = frames.astype(L.COMPUTE_DTYPE)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    x = lshard(x, "batch", "frames", "embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]

    def body(x, p):
        x, _ = _self_attn(cfg, p, x, positions, causal=False)
        return _mlp(cfg, p, x), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(x, params["enc_ln"], params["enc_lnb"], cfg.norm_eps)


def _cross_kv(cfg, p, memory):
    b, f, _ = memory.shape
    k = (memory @ p["xattn"]["wk"].astype(memory.dtype)).reshape(
        b, f, cfg.n_kv_heads, cfg.hd)
    v = (memory @ p["xattn"]["wv"].astype(memory.dtype)).reshape(
        b, f, cfg.n_kv_heads, cfg.hd)
    return k, v


def _decoder(cfg, params, tokens, memory, *, collect_kv, pos_offset=0):
    b, s = tokens.shape
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    x = x + L.sinusoidal_positions(s + pos_offset, cfg.d_model)[
        None, pos_offset:].astype(x.dtype)
    x = lshard(x, "batch", "seq", "embed")
    positions = jnp.arange(s, dtype=jnp.int32)[None] + pos_offset

    def body(x, p):
        x, kv = _self_attn(cfg, p, x, positions, causal=True)
        xk, xv = _cross_kv(cfg, p, memory)
        x, _ = _self_attn(cfg, p, x, positions, causal=False, prefix="x",
                          kv=(xk, xv))
        x = _mlp(cfg, p, x)
        if collect_kv:
            kv = tuple(lshard(a, "batch", "kv_seq", "kv_heads", "head_dim")
                       for a in kv)
        return x, (kv if collect_kv else None)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, kvs = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layernorm(x, params["ln_f"], params["ln_fb"], cfg.norm_eps)
    return x, kvs


def loss_fn(cfg: ModelConfig, params, tokens, labels, frames):
    memory = encode(cfg, params, frames)
    x, _ = _decoder(cfg, params, tokens, memory, collect_kv=False)
    w_out = params["embed"].T  # whisper ties decoder embedding and head
    return L.lm_loss(x, w_out.astype(x.dtype), labels)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    ldim = (cfg.n_layers, batch)
    return dict(
        k=jnp.zeros(ldim + (max_seq, cfg.n_kv_heads, cfg.hd), L.COMPUTE_DTYPE),
        v=jnp.zeros(ldim + (max_seq, cfg.n_kv_heads, cfg.hd), L.COMPUTE_DTYPE),
        xk=jnp.zeros(ldim + (cfg.enc_frames, cfg.n_kv_heads, cfg.hd),
                     L.COMPUTE_DTYPE),
        xv=jnp.zeros(ldim + (cfg.enc_frames, cfg.n_kv_heads, cfg.hd),
                     L.COMPUTE_DTYPE),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill(cfg: ModelConfig, params, tokens, frames):
    memory = encode(cfg, params, frames)
    x, kvs = _decoder(cfg, params, tokens, memory, collect_kv=True)
    logits = (x[:, -1] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)

    def per_layer_xkv(p):
        return _cross_kv(cfg, p, memory)

    xk, xv = jax.vmap(per_layer_xkv)(params["dec_layers"])
    cache = dict(k=kvs[0], v=kvs[1], xk=xk, xv=xv,
                 pos=jnp.asarray(tokens.shape[1], jnp.int32))
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    pos = cache["pos"]
    b = tokens.shape[0]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    # sinusoidal position at `pos` (computed directly, no table)
    dmod = cfg.d_model
    dim = jnp.arange(0, dmod, 2, jnp.float32)
    angle = pos.astype(jnp.float32) / jnp.power(10000.0, dim / dmod)
    pe = jnp.zeros((dmod,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(angle)).at[1::2].set(jnp.cos(angle))
    x = x + pe[None, None].astype(x.dtype)

    def body(x, inputs):
        p, kc, vc, xk, xv = inputs
        h = L.layernorm(x, p["ln1"], p["ln1b"], cfg.norm_eps)
        positions = jnp.full((b, 1), pos, jnp.int32)
        q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, positions, rope_theta=cfg.rope_theta,
                             use_rope=False)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        out = L.decode_attention_ref(q, kc, vc, pos + 1)
        x = x + out.reshape(b, 1, -1) @ p["attn"]["wo"].astype(x.dtype)
        hx = L.layernorm(x, p["lnx"], p["lnxb"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"].astype(x.dtype)).reshape(
            b, 1, cfg.n_heads, cfg.hd)
        outx = L.decode_attention_ref(qx, xk, xv, xk.shape[1])
        x = x + outx.reshape(b, 1, -1) @ p["xattn"]["wo"].astype(x.dtype)
        x = _mlp(cfg, p, x)
        return x, (kc, vc)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
    )
    x = L.layernorm(x, params["ln_f"], params["ln_fb"], cfg.norm_eps)
    logits = (x[:, 0] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, dict(k=ks, v=vs, xk=cache["xk"], xv=cache["xv"], pos=pos + 1)
