"""RWKV6 "Finch": attention-free LM with data-dependent decay.

Per layer: a time-mixing block (multi-head matrix-valued recurrent state,
decay ``w_t`` produced by a LoRA on the token-shifted input) and a
channel-mixing block (squared-ReLU FFN with receptance gate). All
projections run over the full sequence on the MXU; only the rank-1 state
update ``S ← diag(w_t) S + k_t v_tᵀ`` lives in the scan (see
``recurrent.chunked_time_scan``).

State per layer: S (B, H, D, D) f32, plus two token-shift carries (B, d).
Serving integrates with the snapshot store via *state snapshot chains*
(DESIGN §4): the (tiny, fixed-size) state is the unit of COW forking, not
KV pages.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models import layers as L
from repro.models import recurrent as R

LORA_RANK = 64


def _layer_init(cfg: ModelConfig, key):
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    return dict(
        ln1_g=jnp.ones((d,), L.PARAM_DTYPE),
        ln1_b=jnp.zeros((d,), L.PARAM_DTYPE),
        ln2_g=jnp.ones((d,), L.PARAM_DTYPE),
        ln2_b=jnp.zeros((d,), L.PARAM_DTYPE),
        # time-mix
        mu=0.5 * jnp.ones((5, d), L.PARAM_DTYPE),  # r,k,v,w,g shift blends
        w_r=L.dense_init(ks[0], d, d),
        w_k=L.dense_init(ks[1], d, d),
        w_v=L.dense_init(ks[2], d, d),
        w_g=L.dense_init(ks[3], d, d),
        wo=L.dense_init(ks[4], d, d, scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers * d)),
        w0=jnp.full((d,), -5.0, L.PARAM_DTYPE),  # decay bias (slow decay)
        w_lora_a=L.dense_init(ks[5], d, LORA_RANK, scale=0.01),
        w_lora_b=L.dense_init(ks[6], LORA_RANK, d, scale=0.01),
        u=(jax.random.normal(ks[7], (d,)) * 0.1).astype(L.PARAM_DTYPE),
        lnx_g=jnp.ones((d,), L.PARAM_DTYPE),
        lnx_b=jnp.zeros((d,), L.PARAM_DTYPE),
        # channel-mix
        mu_ff=0.5 * jnp.ones((2, d), L.PARAM_DTYPE),  # k, r blends
        wk_ff=L.dense_init(ks[8], d, cfg.d_ff),
        wv_ff=L.dense_init(ks[9], cfg.d_ff, d,
                           scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers * cfg.d_ff)),
        wr_ff=L.dense_init(ks[10], d, d),
    )


def init_params(cfg: ModelConfig, key):
    k_embed, k_out, k_layers = jax.random.split(key, 3)
    return dict(
        embed=L.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        ln_f_g=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        ln_f_b=jnp.zeros((cfg.d_model,), L.PARAM_DTYPE),
        w_out=L.dense_init(k_out, cfg.d_model, cfg.vocab_size, scale=0.02),
        layers=jax.vmap(lambda k: _layer_init(cfg, k))(
            jax.random.split(k_layers, cfg.n_layers)
        ),
    )


def _heads(cfg: ModelConfig, x):
    b, s, d = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.ssm_head_dim)


def _time_mix(cfg: ModelConfig, p, x, shift_prev, state):
    """x: (B,S,d). Returns (out, new_shift, new_state, per-step None)."""
    b, s, d = x.shape
    cd = x.dtype
    shifted, new_shift = R.token_shift(x, shift_prev)

    def blend(i):
        m = p["mu"][i].astype(cd)
        return x * m + shifted * (1.0 - m)

    xr, xk, xv, xw, xg = (blend(i) for i in range(5))
    r = _heads(cfg, xr @ p["w_r"].astype(cd))
    k = _heads(cfg, xk @ p["w_k"].astype(cd))
    v = _heads(cfg, xv @ p["w_v"].astype(cd))
    g = xg @ p["w_g"].astype(cd)
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(cd)) @ p["w_lora_b"].astype(cd)
    logw = p["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                     # (B,S,d) data-dep decay
    w = _heads(cfg, w)
    u = _heads(cfg, p["u"].astype(jnp.float32)[None, None, :])[0, 0]  # (H,D)

    if cfg.rwkv_chunked and s > 1:
        state, y4 = _chunked_recurrence(cfg, r, k, v, w, u, state)
        y = y4.reshape(b, s, d)
    else:
        # per-token recurrence: S (B,H,D,E)
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp                 # (B,H,D) each
            kv = k_t[..., :, None] * v_t[..., None, :]
            y = jnp.einsum("bhd,bhde->bhe", r_t,
                           S + u[None, :, :, None] * kv)
            S = w_t[..., :, None] * S + kv
            return S, y

        xs = tuple(
            jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w)
        )
        state, ys = R.chunked_time_scan(step, state, xs,
                                        chunk=cfg.scan_chunk,
                                        remat=cfg.remat)
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)  # (B,S,d) f32
    y = L.layernorm(y.astype(cd), p["lnx_g"], p["lnx_b"])
    out = (y * jax.nn.silu(g)) @ p["wo"].astype(cd)
    return out, new_shift, state


def _chunked_recurrence(cfg: ModelConfig, r, k, v, w, u, state):
    """Chunkwise-parallel RWKV6 recurrence (the TPU-native formulation).

    Derivation: with S_t = diag(w_t) S_{t-1} + k_t v_tᵀ and
    y_t = r_tᵀ S_{t-1} + ((r_t⊙u)·k_t) v_t, let p_t = Π_{τ≤t} w_τ within a
    chunk (p_0 = 1). Then::

        y_t = (r_t ⊙ p_{t-1})ᵀ S_0                       (inter-chunk)
            + Σ_{s<t} ((r_t ⊙ p_{t-1}/p_s)·k_s) v_s      (intra, matmul)
            + ((r_t ⊙ u)·k_t) v_t                        (diagonal bonus)
        S_T = p_T ⊙ S_0 + (k ⊙ p_T/p)ᵀ V                 (one update/chunk)

    The state is read+written once per chunk instead of once per token —
    the recurrence's HBM traffic drops by the chunk length, and the
    intra-chunk term is a (T×T)·(T×D) masked matmul pair on the MXU.
    Chunk length is kept short (32) so the in-chunk decay products stay
    well inside f32 range. Exactness vs the per-token scan is covered by
    tests/test_models_smoke.py::test_rwkv_chunked_matches_scan.
    """
    b, s, h, dh = r.shape
    t = min(cfg.scan_chunk, s)
    assert s % t == 0, f"seq {s} must divide chunk {t}"
    n_chunks = s // t
    f32 = jnp.float32

    def reshape(a):
        return a.astype(f32).reshape(b, n_chunks, t, h, dh).transpose(
            1, 0, 3, 2, 4)                                # (C,B,H,T,D)

    rc, kc, vc, wc = (reshape(a) for a in (r, k, v, w))
    uu = u.astype(f32)                                    # (H,D)

    def chunk_step(S, inp):
        r_, k_, v_, w_ = inp                              # (B,H,T,D)
        p = jnp.cumprod(w_, axis=2)                       # p_t, t=1..T
        p_prev = jnp.concatenate(
            [jnp.ones_like(p[:, :, :1]), p[:, :, :-1]], axis=2)  # p_{t-1}
        q = r_ * p_prev                                   # (B,H,T,D)
        kappa = k_ / jnp.maximum(p, 1e-30)
        scores = jnp.einsum("bhtd,bhsd->bhts", q, kappa)  # (B,H,T,T)
        mask = jnp.tril(jnp.ones((t, t), bool), k=-1)     # strict s<t
        scores = jnp.where(mask[None, None], scores, 0.0)
        y = jnp.einsum("bhts,bhsd->bhtd", scores, v_)     # intra-chunk
        y = y + jnp.einsum("bhtd,bhde->bhte", q, S)       # inter-chunk
        diag = jnp.sum(r_ * uu[None, :, None, :] * k_, axis=-1,
                       keepdims=True)
        y = y + diag * v_                                 # current token
        decay = p[:, :, -1, :]                            # p_T (B,H,D)
        S = decay[..., None] * S + jnp.einsum(
            "bhtd,bhte->bhde", k_ * (decay[:, :, None] /
                                     jnp.maximum(p, 1e-30)), v_)
        return S, y

    if cfg.remat:
        chunk_step = jax.checkpoint(chunk_step)
    state, ys = jax.lax.scan(chunk_step, state, (rc, kc, vc, wc))
    # (C,B,H,T,D) -> (B, S, H, D)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh)
    return state, y


def _channel_mix(p, x, shift_prev):
    cd = x.dtype
    shifted, new_shift = R.token_shift(x, shift_prev)
    mk = p["mu_ff"][0].astype(cd)
    mr = p["mu_ff"][1].astype(cd)
    xk = x * mk + shifted * (1.0 - mk)
    xr = x * mr + shifted * (1.0 - mr)
    k = jnp.square(jax.nn.relu(xk @ p["wk_ff"].astype(cd)))
    return jax.nn.sigmoid(xr @ p["wr_ff"].astype(cd)) * (k @ p["wv_ff"].astype(cd)), new_shift


def _block(cfg: ModelConfig, p, x, att_shift, ffn_shift, state):
    h = L.layernorm(x, p["ln1_g"], p["ln1_b"])
    att, att_shift, state = _time_mix(cfg, p, h, att_shift, state)
    x = x + att
    x = lshard(x, "batch", "seq", "embed")
    h2 = L.layernorm(x, p["ln2_g"], p["ln2_b"])
    ffn, ffn_shift = _channel_mix(p, h2, ffn_shift)
    x = x + ffn
    return lshard(x, "batch", "seq", "embed"), att_shift, ffn_shift, state


def _stack(cfg: ModelConfig, params, x, cache):
    """Scan the layer stack; cache holds (att_shift, ffn_shift, state) (L,...)."""

    def body(x, inputs):
        p, a_s, f_s, st = inputs
        x, a_s, f_s, st = _block(cfg, p, x, a_s, f_s, st)
        return x, (a_s, f_s, st)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (a_s, f_s, st) = jax.lax.scan(
        body, x, (params["layers"], cache["att_shift"], cache["ffn_shift"],
                  cache["state"])
    )
    return x, dict(att_shift=a_s, ffn_shift=f_s, state=st, pos=cache["pos"])


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0):
    lbd = (cfg.n_layers, batch, cfg.d_model)
    return dict(
        att_shift=jnp.zeros(lbd, L.COMPUTE_DTYPE),
        ffn_shift=jnp.zeros(lbd, L.COMPUTE_DTYPE),
        state=jnp.zeros(
            (cfg.n_layers, batch, cfg.n_heads, cfg.ssm_head_dim,
             cfg.ssm_head_dim), jnp.float32
        ),
        pos=jnp.zeros((), jnp.int32),
    )


def loss_fn(cfg: ModelConfig, params, tokens, labels):
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    x, _ = _stack(cfg, params, x, init_cache(cfg, tokens.shape[0]))
    x = L.layernorm(x, params["ln_f_g"], params["ln_f_b"])
    return L.lm_loss(x, params["w_out"].astype(x.dtype), labels)


def prefill(cfg: ModelConfig, params, tokens):
    b, s = tokens.shape
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    x, cache = _stack(cfg, params, x, init_cache(cfg, b))
    x = L.layernorm(x, params["ln_f_g"], params["ln_f_b"])
    logits = (x[:, -1] @ params["w_out"].astype(x.dtype)).astype(jnp.float32)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]  # (B,1,d)
    cache2 = dict(cache)
    cache2["pos"] = cache["pos"]
    x, cache2 = _stack(cfg, params, x, cache2)
    x = L.layernorm(x, params["ln_f_g"], params["ln_f_b"])
    logits = (x[:, 0] @ params["w_out"].astype(x.dtype)).astype(jnp.float32)
    cache2["pos"] = cache["pos"] + 1
    return logits, cache2
