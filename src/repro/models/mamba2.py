"""Mamba2 (SSD) block: selective state-space recurrence.

Projections and the causal depthwise conv run over the full sequence
(MXU-friendly); the diagonal-decay rank-1 state update runs in a chunked
time scan. State per layer: h (B, nH, headD, N) f32 + conv context
(B, K-1, conv_channels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import recurrent as R


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def block_init(cfg: ModelConfig, key):
    d = cfg.d_model
    din = d_inner(cfg)
    nh = n_ssm_heads(cfg)
    conv_ch = din + 2 * cfg.ssm_state
    ks = jax.random.split(key, 4)
    return dict(
        ln=jnp.ones((d,), L.PARAM_DTYPE),
        in_proj=L.dense_init(ks[0], d, 2 * din + 2 * cfg.ssm_state + nh),
        conv_w=(jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(
            L.PARAM_DTYPE),
        conv_b=jnp.zeros((conv_ch,), L.PARAM_DTYPE),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(L.PARAM_DTYPE),
        d_skip=jnp.ones((nh,), L.PARAM_DTYPE),
        dt_bias=jnp.zeros((nh,), L.PARAM_DTYPE),
        norm=jnp.ones((din,), L.PARAM_DTYPE),
        out_proj=L.dense_init(ks[2], din, d,
                              scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers * din)),
    )


def block_apply(cfg: ModelConfig, p, x, conv_prev, ssm_state):
    """x: (B, S, d). Returns (out, new_conv_prev, new_ssm_state)."""
    b, s, d = x.shape
    cd = x.dtype
    din = d_inner(cfg)
    nh = n_ssm_heads(cfg)
    hd = cfg.ssm_head_dim
    st = cfg.ssm_state

    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(cd)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * st], axis=-1)
    xbc, conv_prev = R.causal_depthwise_conv(
        xbc, p["conv_w"], p["conv_b"], prev=conv_prev
    )
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [din, din + st], axis=-1)
    xs = xs.reshape(b, s, nh, hd).astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)                    # (B,S,N)
    cmat = cmat.astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    decay = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None, None] * dt)

    def step(hstate, inp):
        x_t, b_t, c_t, dt_t, a_t = inp
        # hstate: (B, nh, hd, N)
        dbx = jnp.einsum("bh,bhd,bn->bhdn", dt_t, x_t, b_t)
        hstate = a_t[..., None, None] * hstate + dbx
        y = jnp.einsum("bhdn,bn->bhd", hstate, c_t)
        return hstate, y

    xs_t = jnp.moveaxis(xs, 1, 0)
    b_t = jnp.moveaxis(bmat, 1, 0)
    c_t = jnp.moveaxis(cmat, 1, 0)
    dt_t = jnp.moveaxis(dt, 1, 0)
    a_t = jnp.moveaxis(decay, 1, 0)
    ssm_state, ys = R.chunked_time_scan(
        step, ssm_state, (xs_t, b_t, c_t, dt_t, a_t),
        chunk=cfg.scan_chunk, remat=cfg.remat,
    )
    y = jnp.moveaxis(ys, 0, 1)                          # (B,S,nh,hd)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xs
    y = y.reshape(b, s, din).astype(cd)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return x + y @ p["out_proj"].astype(cd), conv_prev, ssm_state


def state_shapes(cfg: ModelConfig, batch: int):
    conv_ch = d_inner(cfg) + 2 * cfg.ssm_state
    return (
        (batch, cfg.ssm_conv - 1, conv_ch),
        (batch, n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state),
    )
