"""Uniform LM interface over all architecture families.

Every family exposes the same five entry points, so train/serve/dryrun
code is architecture-agnostic:

* ``init(key) -> params``
* ``loss(params, batch) -> scalar``          (batch: tokens/labels[/frames])
* ``init_cache(batch, max_seq) -> cache``
* ``prefill(params, batch) -> (logits, cache)``
* ``decode_step(params, cache, tokens) -> (logits, cache)``
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, rwkv6, transformer


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    def init(self, key):
        return _mod(self.cfg).init_params(self.cfg, key)

    def init_shapes(self, key=None):
        """ShapeDtypeStruct pytree of params (no allocation)."""
        key = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(lambda k: self.init(k), key)

    def loss(self, params, batch):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.loss_fn(cfg, params, batch["tokens"],
                                  batch["labels"], batch["frames"])
        return _mod(cfg).loss_fn(cfg, params, batch["tokens"], batch["labels"])

    def init_cache(self, batch: int, max_seq: int):
        return _mod(self.cfg).init_cache(self.cfg, batch, max_seq)

    def prefill(self, params, batch):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.prefill(cfg, params, batch["tokens"], batch["frames"])
        return _mod(cfg).prefill(cfg, params, batch["tokens"])

    def decode_step(self, params, cache, tokens):
        return _mod(self.cfg).decode_step(self.cfg, params, cache, tokens)


def _mod(cfg: ModelConfig):
    return {
        "dense": transformer,
        "moe": transformer,
        "encdec": encdec,
        "ssm": rwkv6,
        "hybrid": hybrid,
    }[cfg.family]


def get_model(cfg: ModelConfig) -> LM:
    return LM(cfg)


def make_batch(cfg: ModelConfig, key, batch: int, seq: int) -> dict[str, Any]:
    """A concrete random batch (smoke tests, examples)."""
    kt, kf = jax.random.split(key)
    out = dict(
        tokens=jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size,
                                  dtype=jnp.int32),
    )
    out["labels"] = jnp.roll(out["tokens"], -1, axis=1)
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            kf, (batch, cfg.enc_frames, cfg.d_model), jnp.float32)
    return out


def batch_specs(cfg: ModelConfig, batch: int, seq: int, *, kind: str):
    """ShapeDtypeStructs for every model input of a given shape cell."""
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if kind == "train":
        specs = dict(tokens=tok, labels=tok)
    elif kind == "prefill":
        specs = dict(tokens=tok)
    elif kind == "decode":
        specs = dict(tokens=jax.ShapeDtypeStruct((batch, 1), jnp.int32))
    else:
        raise ValueError(kind)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.float32)
    return specs
