"""zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

``cfg.n_layers`` Mamba2 blocks, with a single shared (attention + MLP)
block — one parameter set, reused — applied after every ``cfg.attn_every``
Mamba2 layers (zamba2's parameter-saving trick; we omit the per-invocation
LoRA deltas and the [x, x0] concat re-projection, noted in DESIGN.md).

Decode cache: per-layer Mamba2 conv+SSM states, plus one KV cache *per
shared-block application site* (G sites → leading G axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models import layers as L
from repro.models import mamba2 as M


def _n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init_params(cfg: ModelConfig, key):
    k_embed, k_out, k_shared, k_layers = jax.random.split(key, 4)
    ka, kf = jax.random.split(k_shared)
    shared = dict(
        ln1=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        ln2=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        attn=L.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         qkv_bias=False, qk_norm=False,
                         n_layers_scale=max(1, _n_groups(cfg))),
        ff=L.mlp_init(kf, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                      n_layers_scale=max(1, _n_groups(cfg))),
    )
    return dict(
        embed=L.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        ln_f=jnp.ones((cfg.d_model,), L.PARAM_DTYPE),
        w_out=L.dense_init(k_out, cfg.d_model, cfg.vocab_size, scale=0.02),
        shared=shared,
        layers=jax.vmap(lambda k: M.block_init(cfg, k))(
            jax.random.split(k_layers, cfg.n_layers)
        ),
    )


def _shared_fwd(cfg: ModelConfig, p, x, positions):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         positions, rope_theta=cfg.rope_theta)
    attn = L.attention_ref(q, k, v, causal=True)
    attn = attn.reshape(x.shape[0], x.shape[1], cfg.n_heads * cfg.hd)
    x = x + attn @ p["attn"]["wo"].astype(x.dtype)
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["ff"], h2, cfg.activation)
    k = lshard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = lshard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return lshard(x, "batch", "seq", "embed"), (k, v)


def _shared_decode(cfg: ModelConfig, p, x, k_cache, v_cache, pos):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                         positions, rope_theta=cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
    k_cache = lshard(k_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    v_cache = lshard(v_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    attn = L.decode_attention_ref(q, k_cache, v_cache, pos + 1)
    attn = attn.reshape(x.shape[0], 1, cfg.n_heads * cfg.hd)
    x = x + attn @ p["attn"]["wo"].astype(x.dtype)
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["ff"], h2, cfg.activation)
    return x, k_cache, v_cache


def _mamba_group(cfg: ModelConfig, group_params, x, conv_prev, ssm_state):
    """Scan `attn_every` Mamba2 blocks. States have leading group-layer dim."""

    def body(x, inputs):
        p, cp, st = inputs
        x, cp, st = M.block_apply(cfg, p, x, cp, st)
        return x, (cp, st)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (cp, st) = jax.lax.scan(body, x, (group_params, conv_prev, ssm_state))
    return x, cp, st


def _slice_group(tree, g, size):
    return jax.tree.map(lambda a: a[g * size:(g + 1) * size], tree)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    conv_shape, ssm_shape = M.state_shapes(cfg, batch)
    g = _n_groups(cfg)
    return dict(
        conv=jnp.zeros((cfg.n_layers,) + conv_shape, L.COMPUTE_DTYPE),
        ssm=jnp.zeros((cfg.n_layers,) + ssm_shape, jnp.float32),
        k=jnp.zeros((g, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                    L.COMPUTE_DTYPE),
        v=jnp.zeros((g, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                    L.COMPUTE_DTYPE),
        pos=jnp.zeros((), jnp.int32),
    )


def _forward(cfg: ModelConfig, params, tokens, cache, *, collect_kv: bool):
    b, s = tokens.shape
    ae = cfg.attn_every
    g = _n_groups(cfg)
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    x = lshard(x, "batch", "seq", "embed")
    positions = jnp.arange(s, dtype=jnp.int32)[None] + cache["pos"]
    convs, ssms, kvs = [], [], []
    for gi in range(g):
        gp = _slice_group(params["layers"], gi, ae)
        cp = cache["conv"][gi * ae:(gi + 1) * ae]
        st = cache["ssm"][gi * ae:(gi + 1) * ae]
        x, cp, st = _mamba_group(cfg, gp, x, cp, st)
        convs.append(cp)
        ssms.append(st)
        x, kv = _shared_fwd(cfg, params["shared"], x, positions)
        kvs.append(kv)
    # trailing mamba layers (n_layers % attn_every)
    rem = cfg.n_layers - g * ae
    if rem:
        gp = _slice_group(params["layers"], g, ae)  # partial slice
        gp = jax.tree.map(lambda a: a[-rem:] if a.shape[0] != rem else a, gp)
        cp = cache["conv"][g * ae:]
        st = cache["ssm"][g * ae:]
        x, cp, st = _mamba_group(cfg, gp, x, cp, st)
        convs.append(cp)
        ssms.append(st)
    new_cache = dict(
        conv=jnp.concatenate(convs, axis=0),
        ssm=jnp.concatenate(ssms, axis=0),
        k=jnp.stack([kv[0] for kv in kvs]) if collect_kv else cache["k"],
        v=jnp.stack([kv[1] for kv in kvs]) if collect_kv else cache["v"],
        pos=cache["pos"] + s,
    )
    return x, new_cache


def loss_fn(cfg: ModelConfig, params, tokens, labels):
    cache = init_cache(cfg, tokens.shape[0], 0)
    x, _ = _forward(cfg, params, tokens, cache, collect_kv=False)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.lm_loss(x, params["w_out"].astype(x.dtype), labels)


def prefill(cfg: ModelConfig, params, tokens):
    cache = init_cache(cfg, tokens.shape[0], 0)
    x, cache = _forward(cfg, params, tokens, cache, collect_kv=True)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ params["w_out"].astype(x.dtype)).astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    ae = cfg.attn_every
    g = _n_groups(cfg)
    pos = cache["pos"]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    convs, ssms, ks, vs = [], [], [], []
    for gi in range(g):
        gp = _slice_group(params["layers"], gi, ae)
        cp = cache["conv"][gi * ae:(gi + 1) * ae]
        st = cache["ssm"][gi * ae:(gi + 1) * ae]
        x, cp, st = _mamba_group(cfg, gp, x, cp, st)
        convs.append(cp)
        ssms.append(st)
        x, kc, vc = _shared_decode(cfg, params["shared"], x,
                                   cache["k"][gi], cache["v"][gi], pos)
        ks.append(kc)
        vs.append(vc)
    rem = cfg.n_layers - g * ae
    if rem:
        gp = _slice_group(params["layers"], g, ae)
        gp = jax.tree.map(lambda a: a[-rem:] if a.shape[0] != rem else a, gp)
        cp = cache["conv"][g * ae:]
        st = cache["ssm"][g * ae:]
        x, cp, st = _mamba_group(cfg, gp, x, cp, st)
        convs.append(cp)
        ssms.append(st)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ params["w_out"].astype(x.dtype)).astype(jnp.float32)
    new_cache = dict(
        conv=jnp.concatenate(convs, axis=0),
        ssm=jnp.concatenate(ssms, axis=0),
        k=jnp.stack(ks),
        v=jnp.stack(vs),
        pos=pos + 1,
    )
    return logits, new_cache
