"""chameleon-34b [vlm]: early-fusion, VQ image tokens (backbone only; the
VQ tokenizer is a stub — image tokens are ids in the 65536 vocab).
qk-norm per the paper's stability fix. [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    activation="silu", qk_norm=True, rope_theta=1e4,
)
