"""Model + shape configuration dataclasses and the arch registry."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    activation: str = "silu"    # silu | gelu | relu2
    gated_mlp: bool = True
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0         # hybrid: shared attn block every k layers
    # enc-dec
    n_enc_layers: int = 0
    enc_frames: int = 0         # stub audio/vision frontend sequence length
    use_rope: bool = True       # False → learned/sinusoidal positions
    # distribution knobs (set by the launcher, not part of the arch)
    dispatch_groups: int = 1    # MoE local-dispatch groups (= DP shards)
    remat: bool = True          # activation checkpointing per layer
    scan_chunk: int = 64        # recurrence time-chunk (SSM/RWKV families)
    rwkv_chunked: bool = False  # chunkwise-parallel (matmul) RWKV recurrence
    cache_f32: bool = False     # decode KV cache storage dtype (perf knob:
                                # avoids per-layer full-cache converts on
                                # backends that legalize bf16 dots to f32)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "ssm":                       # rwkv6 time+channel mix
            d_att = d
            per = 4 * d * d_att + d_att * d + 2 * d * self.d_ff + self.d_ff * 0
            per += d * self.d_ff  # receptance path
            blocks = self.n_layers * per
        elif self.family == "hybrid":                  # mamba2 blocks + shared attn
            d_in = self.ssm_expand * d
            mamba = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * d
            blocks = self.n_layers * mamba
            # ONE shared attention+MLP block (zamba2's parameter trick)
            blocks += attn + (3 if self.gated_mlp else 2) * d * self.d_ff
        elif self.is_moe:
            mlp = (3 if self.gated_mlp else 2) * d * self.moe_d_ff
            routed = self.n_experts * mlp
            shared = self.n_shared_experts * mlp
            router = d * self.n_experts
            blocks = self.n_layers * (attn + routed + shared + router)
        else:
            mlp = (3 if self.gated_mlp else 2) * d * self.d_ff
            blocks = self.n_layers * (attn + mlp)
            if self.family == "encdec":
                blocks += self.n_enc_layers * (attn + mlp) + self.n_layers * attn
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return blocks + embed

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        mlp = (3 if self.gated_mlp else 2) * d * self.moe_d_ff
        active = self.n_layers * (
            self.hd * d * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.hd * d
            + (self.top_k + self.n_shared_experts) * mlp + d * self.n_experts
        )
        return active + self.vocab_size * d * (1 if self.tie_embeddings else 2)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence handling; per the assignment it runs
# only for SSM/hybrid archs (see DESIGN.md §4 shape-skip note).
LONG_CONTEXT_ARCHS = ("rwkv6-3b", "zamba2-2.7b")


def cells_for(arch: str) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return cells
