"""whisper-base [audio]: enc-dec, conv frontend stubbed (precomputed frame
embeddings from input_specs). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,            # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    gated_mlp=False,
    use_rope=False,        # whisper uses sinusoidal/learned positions
    enc_frames=1500,
    tie_embeddings=True,
)
