"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,  # 40 heads of 64
    d_ff=8960, vocab_size=65536,
    activation="relu2", gated_mlp=False, use_rope=False,
    ssm_head_dim=64,
)
