"""zamba2-2.7b [hybrid]: Mamba2 backbone + one shared attention block
applied every `attn_every` layers, ssm_state=64. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    activation="gelu", gated_mlp=True,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    attn_every=6,
)
