"""Arch registry: ``get_config(arch_id)``, smoke-reduced variants, shapes."""

from __future__ import annotations

import dataclasses

from repro.configs.base import (  # noqa: F401
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    cells_for,
)

from repro.configs import (  # noqa: E402
    chameleon_34b,
    nemotron_4_15b,
    phi3_5_moe,
    qwen2_5_3b,
    qwen2_7b,
    qwen2_72b,
    qwen2_moe_a2_7b,
    rwkv6_3b,
    whisper_base,
    zamba2_2_7b,
)

_REGISTRY = {
    c.CONFIG.name: c.CONFIG
    for c in (
        whisper_base, qwen2_72b, qwen2_5_3b, nemotron_4_15b, qwen2_7b,
        chameleon_34b, qwen2_moe_a2_7b, phi3_5_moe, rwkv6_3b, zamba2_2_7b,
    )
}


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    try:
        return _REGISTRY[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; known: {list_archs()}") from None


def smoke_config(arch: str) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests.

    Small widths/depths/vocab, few experts — preserves every structural
    feature of the full config (GQA ratio, bias, activation, MoE topology,
    hybrid period, enc-dec split).
    """
    c = get_config(arch)
    kv = max(1, min(c.n_kv_heads, 2 if c.n_kv_heads < c.n_heads else 4))
    heads = 4 if c.n_heads != c.n_kv_heads else kv
    if c.n_heads == c.n_kv_heads:
        heads = kv = 4
    updates = dict(
        n_layers=min(c.n_layers, 4 if c.family == "hybrid" else 2),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if c.is_moe:
        updates.update(n_experts=4, top_k=min(c.top_k, 2), moe_d_ff=32,
                       n_shared_experts=min(c.n_shared_experts, 1))
    if c.family == "encdec":
        updates.update(n_enc_layers=2, enc_frames=12)
    if c.family == "ssm":
        updates.update(n_heads=4, n_kv_heads=4, ssm_head_dim=16)
    if c.family == "hybrid":
        updates.update(ssm_head_dim=16, ssm_state=8, attn_every=2,
                       n_heads=4, n_kv_heads=4)
    return dataclasses.replace(c, **updates)
