"""The paper's own evaluation configuration (§6.1), as data.

These are the constants of the sQEMU testbed, used by the benchmark
harness to scale our page-level reproduction to the paper's geometry and
by ``core.metrics`` to evaluate Eq. 1 / Eq. 2 at paper scale.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperSetup:
    # virtual-disk geometry
    disk_sizes_bytes: tuple = (50 * 2**30, 150 * 2**30)
    cluster_bytes: int = 64 * 1024
    l2_entry_bytes: int = 8
    # chain workload (§3, §6.1)
    chain_lengths: tuple = (1, 50, 100, 500, 1000)
    streaming_threshold: int = 30          # provider policy, Take-away 2
    fill_fraction_micro: float = 0.90      # dd experiments
    fill_fraction_macro: float = 0.25      # RocksDB experiments
    # cache sweep (30%..100% of full-disk L2 coverage)
    cache_fracs: tuple = (0.3, 0.5, 0.75, 1.0)
    default_l2_cache_bytes: int = 1 << 20  # qemu default max
    # timing constants of their testbed (Eq. 1)
    t_ram_s: float = 100e-9
    t_disk_s: float = 80e-6
    t_layers_s: float = 1e-6

    def l2_cache_bytes_full(self, disk_bytes: int) -> int:
        """Cache size that indexes the whole disk (their 'otherwise
        indicated' default): 2.5 MB per 20 GB, i.e. 6.25 MB @ 50 GB."""
        n_clusters = disk_bytes // self.cluster_bytes
        return n_clusters * self.l2_entry_bytes


SETUP = PaperSetup()


def headline_claims() -> dict:
    """The paper's numbers the reproduction validates against
    (EXPERIMENTS.md §Paper-validation)."""
    return dict(
        rocksdb_throughput_gain_at_500=0.48,
        memory_reduction_at_500=15.2,
        memory_reduction_at_1000=17.6,
        dd_slowdown_vanilla_at_1000=0.84,
        boot_time_factor_vanilla_at_1000=4.0,
        boot_time_factor_scalable_at_1000=1.7,
        snapshot_overhead_bytes_50gb=6 * 2**20,
        snapshot_time_ratio_50gb=7.0,
    )
