"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4, per-expert
d_ff=1408. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    qkv_bias=True, activation="silu", rope_theta=1e6,
    n_experts=60, n_shared_experts=4, top_k=4, moe_d_ff=1408,
)
