"""data subsystem."""
