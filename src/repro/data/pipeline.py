"""Deterministic synthetic data pipeline.

``batch_at(step)`` is a pure function of (seed, step) — any worker can
reproduce any batch, which is what makes checkpoint/restart and elastic
re-slicing trivial: the pipeline "state" is just the step counter, carried
inside the checkpointed training state. Per-host sharding slices the
global batch by process index (single-process here, but the slicing logic
is exercised by tests).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pattern: str = "lcg"   # "lcg" (learnable recurrence) | "uniform"
    n_processes: int = 1
    process_index: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_processes == 0
        return self.global_batch // self.n_processes


def batch_at(cfg: DataConfig, step: int, *, with_frames: int = 0,
             d_model: int = 0):
    """Global batch for ``step``, sliced to this process.

    Tokens follow a noisy affine recurrence (``pattern="lcg"``):
    ``t_{i+1} = (a·t_i + c) mod V`` with probability 0.9, uniform noise
    otherwise — *learnable* structure, so example training curves actually
    descend below the uniform-entropy floor. ``pattern="uniform"`` gives
    pure iid tokens (benchmarks)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    kt, kf = jax.random.split(key)
    if cfg.pattern == "uniform":
        tokens = jax.random.randint(
            kt, (cfg.global_batch, cfg.seq_len), 0, cfg.vocab_size,
            dtype=jnp.int32)
    else:
        k0, kn, km = jax.random.split(kt, 3)
        start = jax.random.randint(k0, (cfg.global_batch,), 0,
                                   cfg.vocab_size, dtype=jnp.int32)
        noise = jax.random.randint(kn, (cfg.global_batch, cfg.seq_len), 0,
                                   cfg.vocab_size, dtype=jnp.int32)
        keep = jax.random.uniform(km, (cfg.global_batch, cfg.seq_len)) < 0.9
        a, c = 31, 17

        def step_fn(tok, inp):
            nz, kp = inp
            nxt = jnp.where(kp, (a * tok + c) % cfg.vocab_size, nz)
            return nxt, nxt

        _, seq = jax.lax.scan(
            step_fn, start,
            (noise.T, keep.T))
        tokens = jnp.concatenate([start[:, None], seq.T[:, :-1]], axis=1)
    lo = cfg.process_index * cfg.local_batch
    tokens = tokens[lo:lo + cfg.local_batch]
    batch = dict(tokens=tokens, labels=jnp.roll(tokens, -1, axis=1))
    if with_frames:
        frames = jax.random.normal(
            kf, (cfg.global_batch, with_frames, d_model), jnp.float32
        )[lo:lo + cfg.local_batch]
        batch["frames"] = frames
    return batch
