"""Logical-axis sharding: DP/FSDP/TP/EP/SP rules → PartitionSpecs.

Model code annotates activations with *logical* axis names
(``lshard(x, "batch", "seq", "embed")``); the launcher activates a rule set
mapping logical names to mesh axes. With no active rules (unit tests,
single-device smoke runs) every annotation is a no-op.

Rules ship in two flavours keyed by the production meshes
(DESIGN.md §5):

* single-pod ``(data=16, model=16)``: batch/fsdp → ``data``; tensor/expert/
  sequence parallel → ``model``.
* multi-pod ``(pod=2, data=16, model=16)``: batch additionally shards over
  ``pod`` (pure DP across pods; ZeRO stays within a pod so optimizer-state
  all-gathers never cross the inter-pod links).

Divisibility guard: a dimension that does not divide by the mapped mesh
axes is silently left unsharded (e.g. whisper's 8 heads on a 16-way model
axis). This keeps one rule set valid for all 10 architectures.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(_axis_size(mesh, a) for a in axis)
    return mesh.shape[axis]


class Rules:
    """Mapping: logical axis name -> mesh axis (str | tuple | None)."""

    def __init__(self, mapping: dict, mesh: Mesh):
        self.mapping = dict(mapping)
        self.mesh = mesh

    def resolve(self, name: Optional[str], dim_size: Optional[int] = None):
        if name is None:
            return None
        axis = self.mapping.get(name)
        if axis is None:
            return None
        if isinstance(axis, (tuple, list)):
            axis = tuple(a for a in axis if a in self.mesh.axis_names)
            if not axis:
                return None
        elif axis not in self.mesh.axis_names:
            return None
        if dim_size is not None:
            size = _axis_size(self.mesh, axis)
            if size == 0 or dim_size % size != 0:
                return None  # divisibility guard: leave unsharded
        return tuple(axis) if isinstance(axis, (tuple, list)) else axis

    def spec(self, names: Sequence[Optional[str]], shape=None) -> P:
        dims = list(shape) if shape is not None else [None] * len(names)
        out, used = [], set()
        for n, d in zip(names, dims):
            axis = self.resolve(n, d)
            axes = axis if isinstance(axis, tuple) else (axis,)
            if axis is None or any(a in used for a in axes):
                out.append(None)  # a mesh axis may appear at most once
                continue
            used.update(axes)
            out.append(axis)
        return P(*out)


def make_rules(mesh: Mesh, *, seq_shard: bool = False) -> Rules:
    mapping = {
        "batch": ("pod", "data"),
        # SP: sharding the sequence dim of the residual stream over the
        # model axis divides saved-activation memory by |model| at the cost
        # of per-layer activation all-gathers around attention (perf knob,
        # see EXPERIMENTS.md §Perf)
        "seq": "model" if seq_shard else None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        "fsdp": "data",          # ZeRO param/optimizer sharding (intra-pod)
        "expert": "model",       # EP shares the model axis
        "dispatch": ("pod", "data"),
        "kv_seq": "model",       # decode KV caches: sequence-sharded
        "frames": None,
        "ssm_heads": "model",
        "state": None,
    }
    return Rules(mapping, mesh)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def active_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


def lshard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the active logical sharding; no-op without rules."""
    rules = active_rules()
    if rules is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    spec = rules.spec(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


# ---------------------------------------------------------------------------
# parameter sharding: name-based rules over the trailing dims of each leaf
# ---------------------------------------------------------------------------

# leaf-name -> logical names of the *trailing* dims. Leading (stacked-layer,
# expert, group) dims are padded with None unless matched by a 3-dim rule.
_PARAM_RULES: dict[str, tuple] = {
    # attention
    "wq": ("fsdp", "heads"),
    "wk": ("fsdp", "kv_heads"),
    "wv": ("fsdp", "kv_heads"),
    "wo": ("heads", "fsdp"),
    # mlp
    "w_up": ("fsdp", "ff"),
    "w_gate": ("fsdp", "ff"),
    "w_down": ("ff", "fsdp"),
    # embeddings / head
    "embed": ("vocab", "fsdp"),
    "w_out": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    # moe (leading expert dim matched by rank-3 lookup below)
    "router": ("fsdp", None),
    "e_up": ("expert", "fsdp", None),
    "e_gate": ("expert", "fsdp", None),
    "e_down": ("expert", None, "fsdp"),
    # ssm / rwkv
    "in_proj": ("fsdp", "ff"),
    "out_proj": ("ff", "fsdp"),
    "w_r": ("fsdp", "ff"),
    "w_k": ("fsdp", "ff"),
    "w_v": ("fsdp", "ff"),
    "w_g": ("fsdp", "ff"),
    "wk_ff": ("fsdp", "ff"),
    "wv_ff": ("ff", "fsdp"),
    "wr_ff": ("fsdp", None),
}


# decode/prefill cache leaves, matched by name + rank (trailing dims rule)
_CACHE_RULES: dict[str, tuple] = {
    "k": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    "xk": (None, "batch", None, "kv_heads", "head_dim"),
    "xv": (None, "batch", None, "kv_heads", "head_dim"),
    "conv": (None, "batch", None, None),
    "ssm": (None, "batch", "ssm_heads", None, None),
    "state": (None, "batch", "ssm_heads", None, None),
    "att_shift": (None, "batch", None),
    "ffn_shift": (None, "batch", None),
    "pos": (),
}


def cache_specs(cache: Any, rules: Rules) -> Any:
    def visit(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        rule = _CACHE_RULES.get(name)
        if rule is None or len(rule) != len(leaf.shape):
            rule = (None,) * len(leaf.shape)
        return rules.spec(rule, leaf.shape)

    return jax.tree_util.tree_map_with_path(visit, cache)


def batch_spec(batch: Any, rules: Rules) -> Any:
    """Model inputs: shard axis 0 (global batch) over the DP axes."""
    return jax.tree.map(
        lambda leaf: rules.spec(
            ("batch",) + (None,) * (len(leaf.shape) - 1), leaf.shape
        ),
        batch,
    )


def param_spec(path: str, shape: tuple, rules: Rules) -> P:
    leaf = path.split("/")[-1]
    rule = _PARAM_RULES.get(leaf)
    if rule is None or len(shape) < len(rule):
        return P(*([None] * len(shape)))
    pad = len(shape) - len(rule)
    names = (None,) * pad + tuple(rule)
    return rules.spec(names, shape)


def param_specs(params: Any, rules: Rules) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""

    def visit(path, leaf):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        return param_spec(name, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(params: Any, rules: Rules) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s),
        param_specs(params, rules),
        is_leaf=lambda x: isinstance(x, P),
    )
