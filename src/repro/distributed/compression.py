"""Gradient compression: int8 quantized all-reduce with error feedback.

For pure-DP replicas (e.g. the cross-pod axis, where links are scarcest)
the gradient all-reduce can ship int8 + one f32 scale per tensor — 4x less
wire traffic — with the quantization residual carried to the next step
(error feedback), which keeps SGD convergence unaffected to first order.

``compressed_psum`` is the shard_map building block; ``make_dp_train_step``
wires it into a manual-collective DP training step (params replicated,
batch sharded) used by the rwkv6/small-arch recipes and the compression
benchmark. The FSDP/TP paths keep XLA-inserted collectives (compression
there would sit on the critical path of the matmuls).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import adamw


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 psum. Returns (summed, new_err).

    Wire traffic is 1 byte/element + one scale (vs 4); numerically the sum
    of dequantized values (what an int8 ring all-reduce computes).
    """
    y = x.astype(jnp.float32) + err
    q, scale = quantize_int8(y)
    deq = q.astype(jnp.float32) * scale
    return jax.lax.psum(deq, axis_name), y - deq


def wire_bytes(tree: Any, *, compressed: bool) -> int:
    n = sum(l.size for l in jax.tree.leaves(tree))
    return n * (1 if compressed else 4) + (4 * len(jax.tree.leaves(tree))
                                           if compressed else 0)


def make_dp_train_step(model, opt_cfg: adamw.AdamWConfig, mesh,
                       *, compress: bool = True, axis: str = "data"):
    """Manual-collective pure-DP train step (params replicated).

    Returns step(params, opt_state, err, batch) -> (params, opt, err, loss).
    """

    def local_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        n = jax.lax.psum(1, axis)
        if compress:
            out = jax.tree.map(
                lambda g, e: compressed_psum(g / n, e, axis), grads, err
            )
            grads = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        else:
            grads = jax.tree.map(lambda g: jax.lax.psum(g / n, axis), grads)
        params, opt_state, _ = adamw.apply(opt_cfg, grads, opt_state, params)
        loss = jax.lax.psum(loss, axis) / n
        return params, opt_state, err, loss

    return jax.jit(
        jax.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(P(), P(), P(), P(axis)),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )
    )


def init_error_state(params: Any):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
