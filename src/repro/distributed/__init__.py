"""Distribution: sharding rules, meshes, collectives, compression."""
