"""The fleet's cross-plane invariant suite — shared by tests, the
scenario harness (``tests/scenario/harness.py``) and migration's
attach-time verification.

These are the *structural* contracts that every maintenance, tiering,
serving and migration op must preserve, promoted out of the test files so
one implementation is checked everywhere:

* **Lease non-aliasing** (``check_fleet_invariants``): leases are
  disjoint, every hot L2 pointer sits inside its owner's quanta, and the
  allocator's free set is exactly the complement of the held set —
  the no-cross-tenant-aliasing property the lease-quantum allocator
  exists to provide (docs/architecture.md).
* **Cold-residency consistency**: a tenant's ``cold_count`` equals the
  number of distinct host rows its ``FLAG_COLD`` entries reference, cold
  rows never alias across tenants, and — given the ``TieredStore`` —
  every cold pointer addresses a live (allocated, un-freed) host row.
* **Free-list disjointness** (``TieredStore``): no host row is both free
  and referenced, and no row is on the free list twice.
* **Refcount/tombstone sanity** (``check_kv_invariants``): the serving
  plane's block refcounts equal the per-sequence reference sets, freed
  blocks are never refcounted, tombstones exist only while descendants
  pin them, and the host-spill bookkeeping (``seq.cold`` vs ``_cold_kv``)
  agrees.

All checks are host-side and raise ``AssertionError`` with a labelled
message on the first violation; they read fleet/store/cache state but
never mutate it. The KV cache's private fleet is a *metadata* plane whose
lease allocator is idle (see ``kvcache/paged.py``), so
``check_kv_invariants`` does not run the lease checks against it.
"""

from __future__ import annotations

import numpy as np

from repro.core import format as fmt


def _tenant_cold_rows(l2_t: np.ndarray, length_t: int) -> np.ndarray:
    """Distinct host rows the tenant's COLD entries reference."""
    entries = l2_t[:length_t]
    coldm = (np.asarray(fmt.entry_cold(entries))
             & np.asarray(fmt.entry_allocated(entries))
             & ~np.asarray(fmt.entry_zero(entries)))
    return np.unique(np.asarray(fmt.entry_ptr(entries))[coldm].astype(np.int64))


def check_fleet_invariants(fl, *, store=None, check_leases: bool = True,
                           registry=None) -> None:
    """Assert the structural invariants of a ``ChainFleet`` (and, when
    given, the ``TieredStore`` behind it).

    ``check_leases=False`` skips the lease/row-ownership checks for
    fleets whose lease allocator is deliberately idle (the KV cache's
    metadata plane, where pool rows are refcounted block ids shared
    across tenant rows by design).

    ``registry`` (a ``core.golden.GoldenRegistry``) relaxes the
    no-cross-tenant-aliasing rule in exactly one place: a recorded
    golden *fork* may reference rows inside its base's pinned set —
    tracked aliasing, checked against the registry's per-fork row sets
    and the registry's own bookkeeping (``GoldenRegistry.check``).
    Without a registry, any foreign reference is corruption, as before.
    """
    spec = fl.spec
    q = spec.lease_quantum
    owner = np.asarray(fl.lease_owner)
    index = np.asarray(fl.lease_index)
    count = np.asarray(fl.lease_count)
    alloc = np.asarray(fl.alloc_count)
    lengths = np.asarray(fl.length)
    cold_count = np.asarray(fl.cold_count)
    l2 = np.asarray(fl.l2)

    assert (lengths >= 1).all() and (lengths <= spec.max_chain).all(), \
        "chain length outside [1, max_chain]"

    held_all: list[int] = []
    cold_rows_by_tenant: dict[int, np.ndarray] = {}
    for t in range(spec.n_tenants):
        if check_leases:
            held = index[t, :count[t]]
            assert (held >= 0).all(), f"tenant {t} holds an unstitched lease"
            assert (owner[held] == t).all(), \
                f"tenant {t} lease/owner mismatch"
            assert (index[t, count[t]:] == -1).all(), \
                f"tenant {t} has quantum ids past its lease count"
            assert alloc[t] <= count[t] * q, \
                f"tenant {t} allocated more rows than its leases hold"
            held_all.extend(held.tolist())
        entries = l2[t, :int(lengths[t])]
        allocm = np.asarray(fmt.entry_allocated(entries))
        zerom = np.asarray(fmt.entry_zero(entries))
        coldm = np.asarray(fmt.entry_cold(entries))
        # COLD entries' ptrs address the host tier, not leased device rows
        live = allocm & ~zerom & ~coldm
        rows = np.asarray(fmt.entry_ptr(entries))[live]
        if check_leases and rows.size:
            own = owner[rows // q] == t
            if not own.all():
                # legal exactly when t is a recorded golden fork and the
                # aliased rows sit inside its base's pinned set
                foreign = np.unique(rows[~own]).astype(np.int64)
                allowed = (registry.shared_rows_for(t)
                           if registry is not None else None)
                assert allowed is not None \
                    and np.isin(foreign, allowed).all(), (
                    f"tenant {t} references a foreign row outside any "
                    "registered golden base"
                )
        cold_rows = _tenant_cold_rows(l2[t], int(lengths[t]))
        assert cold_rows.size == int(cold_count[t]), (
            f"tenant {t}: cold_count={int(cold_count[t])} but its L2 "
            f"references {cold_rows.size} distinct host rows"
        )
        if cold_rows.size:
            cold_rows_by_tenant[t] = cold_rows

    if check_leases:
        assert len(held_all) == len(set(held_all)), "quantum leased twice"
        assert sorted(held_all) == sorted(np.flatnonzero(owner >= 0).tolist()), \
            "allocator free set is not the complement of the held set"

    # cold host rows never alias across tenants (each demotion allocates
    # fresh store rows; sharing one would dangle on the first free)
    all_cold = np.concatenate(list(cold_rows_by_tenant.values())) \
        if cold_rows_by_tenant else np.zeros(0, np.int64)
    assert all_cold.size == np.unique(all_cold).size, \
        "host-tier row referenced by more than one tenant"

    if store is not None:
        check_store_invariants(store, referenced=all_cold)

    if registry is not None:
        # the registry's own bookkeeping: frozen owners unchanged, pinned
        # rows still lease-owned by their owner, layer refcounts == forks
        registry.check(fl)


def check_store_invariants(store, *, referenced=None) -> None:
    """``TieredStore`` free-list discipline: free rows are unique, inside
    the allocated range, and disjoint from ``referenced`` (the host rows
    the fleet's COLD entries still address)."""
    free = np.asarray(store._free, np.int64)
    top = store._top
    assert np.unique(free).size == free.size, "host row freed twice"
    if free.size:
        assert free.min() >= 0 and free.max() < top, \
            "free list holds a never-allocated host row"
    assert store.host_rows_in_use() >= 0, "more rows freed than allocated"
    if referenced is not None and len(referenced):
        ref = np.asarray(referenced, np.int64)
        assert ref.min() >= 0 and ref.max() < top, \
            "COLD entry references a never-allocated host row"
        assert not np.isin(ref, free).any(), \
            "COLD entry references a freed host row"


def check_kv_invariants(cache) -> None:
    """Refcount/tombstone/spill sanity of a ``PagedKVCache``.

    The block pool contract: ``_ref[b]`` equals the number of sequences
    (live or tombstoned) holding ``b`` in their reference set, free
    blocks are unreferenced and listed once, tombstones persist only
    while descendants pin them, live sequences own distinct tenant rows
    disjoint from the free-tenant list, and the host-spill sets agree
    between ``seq.cold`` and ``_cold_kv``.
    """
    n_blocks = cache.cfg.n_blocks
    expected = np.zeros(n_blocks, np.int64)
    for seq in cache._seqs.values():
        for b in seq.refs:
            assert 0 <= b < n_blocks, f"sid {seq.sid} refs bad block {b}"
            expected[b] += 1
    for b in cache._reserved:
        expected[b] += 1
    ref = np.asarray(cache._ref, np.int64)
    assert (ref == expected).all(), (
        "block refcounts drifted from the per-sequence reference sets at "
        f"blocks {np.flatnonzero(ref != expected).tolist()}"
    )

    free = list(cache._free)
    assert len(free) == len(set(free)), "KV block freed twice"
    for b in free:
        assert expected[b] == 0, f"block {b} is both free and referenced"

    children = {sid: 0 for sid in cache._seqs}
    for seq in cache._seqs.values():
        if seq.parent is not None and seq.parent in children:
            children[seq.parent] += 1
    for sid, seq in cache._seqs.items():
        assert seq.children == children[sid], (
            f"sid {sid}: children={seq.children} but {children[sid]} "
            "sequences name it as parent"
        )
        if seq.freed:
            # _reap removes freed leaves immediately: a surviving
            # tombstone must be pinned by at least one descendant
            assert seq.children > 0, f"unreaped childless tombstone {sid}"
            assert seq.tenant is None, f"tombstone {sid} still owns a row"
            assert sid not in cache._occupants, \
                f"tombstone {sid} still registered for write fan-out"
        else:
            assert seq.tenant is not None, f"live sid {sid} has no row"
            assert sid in cache._occupants, \
                f"live sid {sid} missing from the occupants registry"

    live_tenants = [s.tenant for s in cache._seqs.values() if not s.freed]
    assert len(live_tenants) == len(set(live_tenants)), \
        "two live sequences share a tenant row"
    assert not set(live_tenants) & set(cache._free_tenants), \
        "a live sequence's tenant row is on the free-tenant list"

    for sid, seq in cache._seqs.items():
        spilled = set(cache._cold_kv.get(sid, {}))
        assert seq.cold == spilled, (
            f"sid {sid}: cold set {sorted(seq.cold)} != host-tier keys "
            f"{sorted(spilled)}"
        )
    for sid in cache._cold_kv:
        assert sid in cache._seqs, f"host spill for unknown sid {sid}"

    # golden (shared-base) bookkeeping: the registration map and the
    # per-sequence flags agree, and a registered prefix is live, fully
    # device-resident, and every block it shares is refcounted
    golden = getattr(cache, "_golden", {})
    for sid in golden:
        assert sid in cache._seqs, f"golden registration for unknown sid {sid}"
        seq = cache._seqs[sid]
        assert not seq.freed, f"golden sid {sid} is tombstoned"
        assert not seq.cold, f"golden sid {sid} holds host-spilled blocks"
        assert seq.length > 0, f"golden sid {sid} is empty"
    for sid, seq in cache._seqs.items():
        flagged = bool(getattr(seq, "golden", False))
        assert flagged == (sid in golden), (
            f"sid {sid}: golden flag {flagged} disagrees with the "
            "registration map"
        )
        if flagged:
            for b in seq.refs:
                assert ref[b] >= 1, \
                    f"golden sid {sid} shares unreferenced block {b}"
