"""Bit-level layout of SnapStore L2 index entries.

This mirrors the paper's extension of the Qcow2 format (sQEMU, §5.2): each
L2 entry describes one data cluster ("page" here) and carries, in previously
reserved bits, a 16-bit ``backing_file_index`` identifying the snapshot in
the chain that owns the latest valid version of the page.

An entry is two little words of uint32 (the on-disk Qcow2 entry is 64-bit;
we keep two u32 words to stay in JAX's default 32-bit world):

``word0`` (data pointer + cluster flags)::

    bits [0, 28)   page_ptr   — row index into the global page pool; for a
                   COLD entry, a row index into the host tier instead
    bit  28        ENCRYPTED  — feature-preservation flag (carried, not used)
    bit  29        COLD       — tier-residency bit: the page was demoted to
                   the host tier and ``ptr`` addresses the ``TieredStore``
                   host pool, not the device pool (repurposes the unused
                   COMPRESSED slot; see ``docs/memory.md``)
    bit  30        ZERO       — "reads as zeros" cluster (qcow2 v3 feature)
    bit  31        ALLOCATED  — entry describes an allocated page

``word1`` (sQEMU extension; all-zero in vanilla-format images)::

    bits [0, 16)   backing_file_index (bfi) — per paper §5.2, 16 bits
    bit  16        BFI_VALID — set iff the image was written/converted in
                   scalable (sQEMU) format. Vanilla images leave word1 = 0,
                   which is how backward compatibility is preserved: a
                   scalable reader falls back to the chain walk when this
                   bit is unset, and a vanilla reader ignores word1 entirely.

The all-zeros entry means "unallocated", exactly as in Qcow2.
"""

from __future__ import annotations

import jax.numpy as jnp

ENTRY_WORDS = 2

PTR_BITS = 28
PTR_MASK = (1 << PTR_BITS) - 1

FLAG_ENCRYPTED = 1 << 28
# Tier-residency bit: repurposes the (never-set) qcow2 COMPRESSED slot.
# When set, ``ptr`` addresses the TieredStore host tier, not the device
# pool — resolvers surface it as ``ResolveResult.cold`` so data-plane
# gathers mask cold hits and maintenance promotes before the read.
FLAG_COLD = 1 << 29
FLAG_ZERO = 1 << 30
FLAG_ALLOCATED = 1 << 31

BFI_BITS = 16  # paper §5.2: "We use 16 bits to encode backing_file_index"
BFI_MASK = (1 << BFI_BITS) - 1
FLAG_BFI_VALID = 1 << BFI_BITS

MAX_CHAIN_REPRESENTABLE = 1 << BFI_BITS
MAX_POOL_ROWS = 1 << PTR_BITS

_U32 = jnp.uint32


def pack_entry(ptr, bfi, *, allocated, bfi_valid, zero=False, cold=False):
    """Pack entry fields into a ``(..., 2) uint32`` array.

    ``ptr``/``bfi`` are integer arrays (broadcastable); ``allocated``,
    ``bfi_valid``, ``zero``, ``cold`` are boolean arrays or python bools.
    A COLD entry's ``ptr`` addresses the host tier (see module docstring).
    """
    ptr = jnp.asarray(ptr, _U32) & _U32(PTR_MASK)
    bfi = jnp.asarray(bfi, _U32) & _U32(BFI_MASK)
    allocated = jnp.asarray(allocated, bool)
    bfi_valid = jnp.asarray(bfi_valid, bool)
    zero = jnp.asarray(zero, bool)
    cold = jnp.asarray(cold, bool)
    w0 = ptr | jnp.where(allocated, _U32(FLAG_ALLOCATED), _U32(0))
    w0 = w0 | jnp.where(zero, _U32(FLAG_ZERO), _U32(0))
    w0 = w0 | jnp.where(cold, _U32(FLAG_COLD), _U32(0))
    w1 = bfi | jnp.where(bfi_valid, _U32(FLAG_BFI_VALID), _U32(0))
    # An unallocated entry is all-zeros (Qcow2 convention).
    w0 = jnp.where(allocated, w0, _U32(0))
    w1 = jnp.where(allocated, w1, _U32(0))
    return jnp.stack([w0, w1], axis=-1)


def empty_entries(shape):
    """All-zero (unallocated) entries of the given leading shape."""
    return jnp.zeros(tuple(shape) + (ENTRY_WORDS,), dtype=_U32)


def entry_ptr(entries):
    return entries[..., 0] & _U32(PTR_MASK)


def entry_allocated(entries):
    return (entries[..., 0] & _U32(FLAG_ALLOCATED)) != 0


def entry_zero(entries):
    return (entries[..., 0] & _U32(FLAG_ZERO)) != 0


def entry_cold(entries):
    """Tier-residency bit: True where ``ptr`` addresses the host tier."""
    return (entries[..., 0] & _U32(FLAG_COLD)) != 0


def entry_bfi(entries):
    return entries[..., 1] & _U32(BFI_MASK)


def entry_bfi_valid(entries):
    return (entries[..., 1] & _U32(FLAG_BFI_VALID)) != 0


def strip_extension(entries):
    """Return the vanilla-format view of scalable entries (word1 zeroed).

    This is what a vanilla (pre-sQEMU) driver sees: the extension lives in
    reserved bits it never reads. Used by backward-compatibility tests.
    """
    w0 = entries[..., 0]
    w1 = jnp.zeros_like(entries[..., 1])
    return jnp.stack([w0, w1], axis=-1)
