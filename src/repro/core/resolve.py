"""Page resolution: the vanilla chain walk vs sQEMU direct access.

Given a batch of logical page ids, resolution answers: *which snapshot owns
the latest version of each page, and at which pool row does it live?*

``resolve_vanilla``
    The vanilla Qcow2 strategy (paper §2): starting from the active volume,
    consult each backing file in turn until an allocated entry is found.
    On TPU this is expressed as a vectorized first-hit scan over the chain
    axis — the cost (bytes touched and index lookups) is O(chain length)
    per request, faithfully modelling the paper's Eq. 1 scaling.

``resolve_direct``
    The sQEMU strategy (paper §5.3): a single lookup of the active volume's
    L2 entry, which carries ``backing_file_index``. O(1) per request.
    Falls back to the chain walk for entries whose BFI_VALID bit is unset
    (vanilla-format images read by a scalable driver — backward compat).

Both return identical ``(owner, ptr)`` on scalable chains — a property the
test suite checks exhaustively (hypothesis) — because pool rows are global.

The actual lookup math lives in the ``*_tables`` helpers, which operate on
bare ``(C, n_pages, 2)`` L2 arrays plus a chain length. The single-chain
entry points are thin wrappers; ``core.fleet`` vmaps the same helpers over
a stacked tenant axis, so one implementation serves both scales.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import format as fmt
from repro.core.chain import Chain


class ResolveResult(NamedTuple):
    owner: jax.Array    # (B,) int32 — owning snapshot index; -1 if not found
    ptr: jax.Array      # (B,) uint32 — pool row (valid only where found)
    found: jax.Array    # (B,) bool
    zero: jax.Array     # (B,) bool — qcow2 "zero cluster"
    lookups: jax.Array  # (B,) int32 — #L2 consultations performed (cost)


def resolve_vanilla_tables(l2: jax.Array, length: jax.Array,
                           page_ids: jax.Array) -> ResolveResult:
    """First-hit scan from the active volume down the chain. O(chain).

    ``l2``: (C, n_pages, 2) uint32; ``length``: () int32; ``page_ids``: (B,).
    """
    max_chain = l2.shape[0]
    page_ids = page_ids.astype(jnp.int32)
    entries = l2[:, page_ids]                             # (C, B, 2)
    live = jnp.arange(max_chain, dtype=jnp.int32)[:, None] < length
    alloc = fmt.entry_allocated(entries) & live           # (C, B)
    idx = jnp.arange(max_chain, dtype=jnp.int32)[:, None]
    owner = jnp.max(jnp.where(alloc, idx, -1), axis=0)    # (B,)
    found = owner >= 0
    picked = jnp.take_along_axis(
        entries, jnp.maximum(owner, 0)[None, :, None], axis=0
    )[0]                                                   # (B, 2)
    # Walk cost: active volume down to the owner (inclusive); a miss walks
    # the entire chain.
    lookups = jnp.where(found, length - owner, length)
    return ResolveResult(
        owner=owner,
        ptr=fmt.entry_ptr(picked),
        found=found,
        zero=fmt.entry_zero(picked) & found,
        lookups=lookups.astype(jnp.int32),
    )


def resolve_direct_tables(l2: jax.Array, length: jax.Array,
                          page_ids: jax.Array) -> ResolveResult:
    """Single active-volume lookup using backing_file_index. O(1)."""
    page_ids = page_ids.astype(jnp.int32)
    active = length - 1
    entries = jax.lax.dynamic_index_in_dim(l2, active, 0, keepdims=False)[page_ids]
    alloc = fmt.entry_allocated(entries)
    valid = fmt.entry_bfi_valid(entries)
    owner = jnp.where(alloc, fmt.entry_bfi(entries).astype(jnp.int32), -1)
    return ResolveResult(
        owner=owner,
        ptr=fmt.entry_ptr(entries),
        found=alloc & valid,
        zero=fmt.entry_zero(entries) & alloc,
        lookups=jnp.ones_like(page_ids),
    )


def resolve_auto_tables(l2: jax.Array, length: jax.Array,
                        page_ids: jax.Array) -> ResolveResult:
    """Direct access where BFI_VALID, chain walk otherwise.

    This is what the sQEMU driver actually does on mixed images (paper
    §5.1 backward compatibility): pages written by a vanilla tool lack the
    extension bits and are resolved by walking; scalable pages are O(1).
    """
    direct = resolve_direct_tables(l2, length, page_ids)
    active = length - 1
    entries = jax.lax.dynamic_index_in_dim(l2, active, 0, keepdims=False)[
        page_ids.astype(jnp.int32)
    ]
    # Trust the direct path iff the active entry is either scalable-valid
    # or genuinely unallocated on a fully-scalable chain. Anything else
    # (allocated-without-bfi, or an empty active volume after a vanilla
    # snapshot) must walk.
    trust = fmt.entry_bfi_valid(entries) & fmt.entry_allocated(entries)
    walk = resolve_vanilla_tables(l2, length, page_ids)
    pick = lambda d, w: jnp.where(trust, d, w)
    return ResolveResult(
        owner=pick(direct.owner, walk.owner),
        ptr=pick(direct.ptr, walk.ptr),
        found=pick(direct.found, walk.found),
        zero=pick(direct.zero, walk.zero),
        lookups=pick(direct.lookups, walk.lookups),
    )


_TABLE_RESOLVERS = {
    "vanilla": resolve_vanilla_tables,
    "direct": resolve_direct_tables,
    "auto": resolve_auto_tables,
}


@jax.jit
def resolve_vanilla(chain: Chain, page_ids: jax.Array) -> ResolveResult:
    return resolve_vanilla_tables(chain.l2, chain.length, page_ids)


@jax.jit
def resolve_direct(chain: Chain, page_ids: jax.Array) -> ResolveResult:
    return resolve_direct_tables(chain.l2, chain.length, page_ids)


@jax.jit
def resolve_auto(chain: Chain, page_ids: jax.Array) -> ResolveResult:
    return resolve_auto_tables(chain.l2, chain.length, page_ids)


_RESOLVERS = {
    "vanilla": resolve_vanilla,
    "direct": resolve_direct,
    "auto": resolve_auto,
}


def lookup_resolver(registry: dict, name: str):
    """Shared registry lookup (chain-, table- and fleet-level registries)."""
    try:
        return registry[name]
    except KeyError:
        raise ValueError(
            f"unknown resolver {name!r}; expected one of {sorted(registry)}"
        ) from None


def get_resolver(name: str):
    return lookup_resolver(_RESOLVERS, name)


def get_table_resolver(name: str):
    """Table-level resolver (used by ``core.fleet`` under vmap)."""
    return lookup_resolver(_TABLE_RESOLVERS, name)
