"""Page resolution: the vanilla chain walk vs sQEMU direct access.

Given a batch of logical page ids, resolution answers: *which snapshot owns
the latest version of each page, and at which pool row does it live?*

``resolve_vanilla``
    The vanilla Qcow2 strategy (paper §2): starting from the active volume,
    consult each backing file in turn until an allocated entry is found.
    On TPU this is expressed as a vectorized first-hit scan over the chain
    axis — the cost (bytes touched and index lookups) is O(chain length)
    per request, faithfully modelling the paper's Eq. 1 scaling.

``resolve_direct``
    The sQEMU strategy (paper §5.3): a single lookup of the active volume's
    L2 entry, which carries ``backing_file_index``. O(1) per request.
    Falls back to the chain walk for entries whose BFI_VALID bit is unset
    (vanilla-format images read by a scalable driver — backward compat).

Both return identical ``(owner, ptr)`` on scalable chains — a property the
test suite checks exhaustively (hypothesis) — because pool rows are global.

The actual lookup math lives in the ``*_tables`` helpers, which operate on
bare ``(C, n_pages, 2)`` L2 arrays plus a chain length. The single-chain
entry points are thin wrappers; ``core.fleet`` vmaps the same helpers over
a stacked tenant axis, so one implementation serves both scales.

A second implementation of each strategy lives in the Pallas kernels of
``kernels/chain_resolve``: the ``resolve_*_stacked`` functions here run
them over the whole stacked (T, C, P) fleet layout in one kernel launch
(compiled on TPU, interpret mode elsewhere — CI exercises the kernel
path on CPU). Single chains reach the same kernels through the
``"pallas_vanilla"``/``"pallas_direct"`` registry entries, which view a
chain as a one-tenant fleet. See ``docs/kernels.md``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import format as fmt
from repro.core.chain import Chain
from repro.kernels.chain_resolve import ops as _kernel_ops


class ResolveResult(NamedTuple):
    owner: jax.Array    # (B,) int32 — owning snapshot index; -1 if not found
    ptr: jax.Array      # (B,) uint32 — pool row (valid only where found);
                        # a host-tier row where ``cold``
    found: jax.Array    # (B,) bool
    zero: jax.Array     # (B,) bool — qcow2 "zero cluster"
    lookups: jax.Array  # (B,) int32 — #L2 consultations performed (cost)
    cold: jax.Array     # (B,) bool — hit lives in the host tier (FLAG_COLD);
                        # device gathers must mask it, promotion makes it hot


def tables_from_hits(owner: jax.Array, hit: jax.Array) -> jax.Array:
    """Direct block tables from a stacked first-hit resolve.

    ``owner``/``hit`` are the outputs of the fleet walk
    (``kernels.chain_resolve``): the owning layer per page (-1 = miss)
    and the owning layer's raw L2 word0. Returns int32 tables — the pool
    row where found, -1 holes — the exact shape the paged-attention
    plane consumes. Shared by the serving plane's table materialization
    and the fused-attention oracle so the hole convention cannot drift.
    """
    ptr = (hit & jnp.uint32(fmt.PTR_MASK)).astype(jnp.int32)
    return jnp.where(owner >= 0, ptr, -1)


def resolve_vanilla_tables(l2: jax.Array, length: jax.Array,
                           page_ids: jax.Array) -> ResolveResult:
    """First-hit scan from the active volume down the chain. O(chain).

    ``l2``: (C, n_pages, 2) uint32; ``length``: () int32; ``page_ids``: (B,).
    """
    max_chain = l2.shape[0]
    page_ids = page_ids.astype(jnp.int32)
    entries = l2[:, page_ids]                             # (C, B, 2)
    live = jnp.arange(max_chain, dtype=jnp.int32)[:, None] < length
    alloc = fmt.entry_allocated(entries) & live           # (C, B)
    idx = jnp.arange(max_chain, dtype=jnp.int32)[:, None]
    owner = jnp.max(jnp.where(alloc, idx, -1), axis=0)    # (B,)
    found = owner >= 0
    picked = jnp.take_along_axis(
        entries, jnp.maximum(owner, 0)[None, :, None], axis=0
    )[0]                                                   # (B, 2)
    # Walk cost: active volume down to the owner (inclusive); a miss walks
    # the entire chain.
    lookups = jnp.where(found, length - owner, length)
    return ResolveResult(
        owner=owner,
        ptr=fmt.entry_ptr(picked),
        found=found,
        zero=fmt.entry_zero(picked) & found,
        lookups=lookups.astype(jnp.int32),
        cold=fmt.entry_cold(picked) & found,
    )


def resolve_direct_tables(l2: jax.Array, length: jax.Array,
                          page_ids: jax.Array) -> ResolveResult:
    """Single active-volume lookup using backing_file_index. O(1)."""
    page_ids = page_ids.astype(jnp.int32)
    active = length - 1
    entries = jax.lax.dynamic_index_in_dim(l2, active, 0, keepdims=False)[page_ids]
    alloc = fmt.entry_allocated(entries)
    valid = fmt.entry_bfi_valid(entries)
    owner = jnp.where(alloc, fmt.entry_bfi(entries).astype(jnp.int32), -1)
    return ResolveResult(
        owner=owner,
        ptr=fmt.entry_ptr(entries),
        found=alloc & valid,
        zero=fmt.entry_zero(entries) & alloc,
        lookups=jnp.ones_like(page_ids),
        cold=fmt.entry_cold(entries) & alloc,
    )


def combine_auto(trust: jax.Array, direct: ResolveResult,
                 walk: ResolveResult) -> ResolveResult:
    """Field-wise pick of ``direct`` where ``trust`` else ``walk``.

    ``trust`` must be "the active entry is allocated AND carries a valid
    backing_file_index" — exactly ``direct.found``. Anything else
    (allocated-without-bfi, or an empty active volume after a vanilla
    snapshot) must fall back to the chain walk. Shared by the jnp and the
    Pallas-kernel auto resolvers so the mixed-image semantics cannot
    drift between implementations.
    """
    pick = lambda d, w: jnp.where(trust, d, w)
    return ResolveResult(
        owner=pick(direct.owner, walk.owner),
        ptr=pick(direct.ptr, walk.ptr),
        found=pick(direct.found, walk.found),
        zero=pick(direct.zero, walk.zero),
        lookups=pick(direct.lookups, walk.lookups),
        cold=pick(direct.cold, walk.cold),
    )


def resolve_auto_tables(l2: jax.Array, length: jax.Array,
                        page_ids: jax.Array) -> ResolveResult:
    """Direct access where BFI_VALID, chain walk otherwise.

    This is what the sQEMU driver actually does on mixed images (paper
    §5.1 backward compatibility): pages written by a vanilla tool lack the
    extension bits and are resolved by walking; scalable pages are O(1).
    """
    direct = resolve_direct_tables(l2, length, page_ids)
    walk = resolve_vanilla_tables(l2, length, page_ids)
    # direct.found is precisely the trust condition: the active entry is
    # allocated and its backing_file_index is valid (scalable-written).
    return combine_auto(direct.found, direct, walk)


_TABLE_RESOLVERS = {
    "vanilla": resolve_vanilla_tables,
    "direct": resolve_direct_tables,
    "auto": resolve_auto_tables,
}


# -- Pallas-kernel resolvers over the stacked (T, C, P, 2) fleet layout ------


def resolve_vanilla_stacked(l2: jax.Array, lengths: jax.Array,
                            page_ids: jax.Array) -> ResolveResult:
    """Kernel-backed first-hit walk for a whole fleet in one launch.

    ``l2``: (T, C, n_pages, 2) uint32 stacked tables; ``lengths``: (T,);
    ``page_ids``: (T, B). The kernel resolves every tenant's *full* page
    table (the walk cost is amortized across the read batch); the batch's
    owners/pointers are then a cheap per-tenant gather. Results are
    bit-identical to ``resolve_vanilla_tables`` vmapped over tenants.
    """
    ids = page_ids.astype(jnp.int32)
    owner_map, hit_map = _kernel_ops.resolve_vanilla_fleet(l2[..., 0], lengths)
    owner = jnp.take_along_axis(owner_map, ids, axis=1)
    hit = jnp.take_along_axis(hit_map, ids, axis=1)
    found = owner >= 0
    ln = lengths.astype(jnp.int32)[:, None]
    return ResolveResult(
        owner=owner.astype(jnp.int32),
        ptr=hit & jnp.uint32(fmt.PTR_MASK),
        found=found,
        # a miss returns hit == 0, so the ZERO/COLD bits read as False there
        zero=(hit & jnp.uint32(fmt.FLAG_ZERO)) != 0,
        lookups=jnp.where(found, ln - owner, ln).astype(jnp.int32),
        cold=(hit & jnp.uint32(fmt.FLAG_COLD)) != 0,
    )


def resolve_direct_stacked(l2: jax.Array, lengths: jax.Array,
                           page_ids: jax.Array) -> ResolveResult:
    """Kernel-backed direct access for a whole fleet in one launch.

    Same contract as ``resolve_vanilla_stacked`` but O(1) per page: the
    kernel's BlockSpec stages only each tenant's active layer (picked by
    the prefetched ``lengths``). Bit-identical to
    ``resolve_direct_tables`` vmapped over tenants.
    """
    ids = page_ids.astype(jnp.int32)
    owner_map, h0_map, h1_map = _kernel_ops.resolve_direct_fleet(
        l2[..., 0], l2[..., 1], lengths
    )
    owner = jnp.take_along_axis(owner_map, ids, axis=1)
    h0 = jnp.take_along_axis(h0_map, ids, axis=1)
    h1 = jnp.take_along_axis(h1_map, ids, axis=1)
    alloc = (h0 & jnp.uint32(fmt.FLAG_ALLOCATED)) != 0
    return ResolveResult(
        owner=owner.astype(jnp.int32),
        ptr=h0 & jnp.uint32(fmt.PTR_MASK),
        found=alloc & ((h1 & jnp.uint32(fmt.FLAG_BFI_VALID)) != 0),
        zero=((h0 & jnp.uint32(fmt.FLAG_ZERO)) != 0) & alloc,
        lookups=jnp.ones_like(ids),
        cold=((h0 & jnp.uint32(fmt.FLAG_COLD)) != 0) & alloc,
    )


def resolve_auto_stacked(l2: jax.Array, lengths: jax.Array,
                         page_ids: jax.Array) -> ResolveResult:
    """Kernel-backed mixed-image resolution: both kernels, then the same
    ``combine_auto`` trust pick as the jnp auto resolver."""
    direct = resolve_direct_stacked(l2, lengths, page_ids)
    walk = resolve_vanilla_stacked(l2, lengths, page_ids)
    return combine_auto(direct.found, direct, walk)


def _stacked_as_chain(fn):
    """Run a stacked kernel resolver on a single chain (a 1-tenant fleet)."""

    @jax.jit
    def resolver(chain: Chain, page_ids: jax.Array) -> ResolveResult:
        res = fn(chain.l2[None], chain.length[None], page_ids[None])
        return ResolveResult(*(leaf[0] for leaf in res))

    return resolver


@jax.jit
def resolve_vanilla(chain: Chain, page_ids: jax.Array) -> ResolveResult:
    return resolve_vanilla_tables(chain.l2, chain.length, page_ids)


@jax.jit
def resolve_direct(chain: Chain, page_ids: jax.Array) -> ResolveResult:
    return resolve_direct_tables(chain.l2, chain.length, page_ids)


@jax.jit
def resolve_auto(chain: Chain, page_ids: jax.Array) -> ResolveResult:
    return resolve_auto_tables(chain.l2, chain.length, page_ids)


_RESOLVERS = {
    "vanilla": resolve_vanilla,
    "direct": resolve_direct,
    "auto": resolve_auto,
    # kernel-backed paths (interpret mode off-TPU): a chain is a 1-tenant
    # fleet, so the stacked Pallas kernels serve single chains too
    "pallas_vanilla": _stacked_as_chain(resolve_vanilla_stacked),
    "pallas_direct": _stacked_as_chain(resolve_direct_stacked),
}


def lookup_resolver(registry: dict, name: str):
    """Shared registry lookup (chain-, table- and fleet-level registries)."""
    try:
        return registry[name]
    except KeyError:
        raise ValueError(
            f"unknown resolver {name!r}; expected one of {sorted(registry)}"
        ) from None


def get_resolver(name: str):
    return lookup_resolver(_RESOLVERS, name)


def get_table_resolver(name: str):
    """Table-level resolver (used by ``core.fleet`` under vmap)."""
    return lookup_resolver(_TABLE_RESOLVERS, name)
