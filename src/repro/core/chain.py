"""Snapshot-chain state and snapshot/streaming operations.

A ``Chain`` is the JAX-native analogue of a Qcow2 backing-file chain:

* a logical "virtual disk" of ``n_pages`` pages of ``page_size`` elements;
* up to ``max_chain`` snapshot layers. Layer ``length - 1`` is the *active
  volume*; layers below it are immutable *backing files*;
* per-layer L1/L2 index arrays (dense; an absent L2 table is all-zeros with
  its L1 presence bit clear — Qcow2's unallocated-table case);
* one global page *pool* shared by all layers (the single-HBM analogue of
  the provider's storage backend). Pool rows are immutable once written;
  COW writes always allocate fresh rows for the active volume.

Two snapshot-creation flavours, as in the paper:

* ``snapshot(chain, scalable=False)`` — vanilla Qcow2: the new active volume
  starts empty, and reads must walk the chain (``resolve.resolve_vanilla``).
* ``snapshot(chain, scalable=True)`` — sQEMU §5.4: the full L1/L2 table set
  of the previous active volume is copied forward, ``backing_file_index``
  preserved, so the new active volume indexes the entire chain and
  ``resolve.resolve_direct`` is O(1).

``stream`` implements chain compaction (the provider's "streaming" job).
It is a host-side maintenance operation (not jitted), matching Qemu where
streaming is a background job outside the guest I/O path.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import format as fmt


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """Static geometry of a chain (hashable; safe as a jit static arg)."""

    n_pages: int
    page_size: int
    max_chain: int
    pool_capacity: int
    l2_per_table: int = 64  # L2 entries per L2 table (qcow2: cluster_size/8)
    slice_len: int = 16     # cache-slice granularity, in entries (qcow2 docs)
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.n_pages % self.l2_per_table != 0:
            raise ValueError("n_pages must be a multiple of l2_per_table")
        if self.max_chain > fmt.MAX_CHAIN_REPRESENTABLE:
            raise ValueError("max_chain exceeds 16-bit backing_file_index")
        if self.pool_capacity > fmt.MAX_POOL_ROWS:
            raise ValueError("pool_capacity exceeds 28-bit page_ptr")
        if self.l2_per_table % self.slice_len != 0:
            raise ValueError("l2_per_table must be a multiple of slice_len")

    @property
    def n_l1(self) -> int:
        return self.n_pages // self.l2_per_table

    @property
    def n_slices(self) -> int:
        return self.n_pages // self.slice_len

    def index_bytes_per_snapshot(self) -> int:
        """On-disk metadata bytes added per snapshot (Eq. 2 numerator)."""
        return self.n_pages * fmt.ENTRY_WORDS * 4 + self.n_l1 * 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Chain:
    spec: ChainSpec = dataclasses.field(metadata=dict(static=True))
    scalable: bool = dataclasses.field(metadata=dict(static=True))
    l1: jax.Array          # (max_chain, n_l1) uint32 — bit0: L2 table present
    l2: jax.Array          # (max_chain, n_pages, 2) uint32 — L2 entries
    pool: jax.Array        # (pool_capacity, page_size) dtype
    pool_cursor: jax.Array  # () int32 — next free pool row
    length: jax.Array      # () int32 — #files in chain; active = length - 1
    overflow: jax.Array      # () bool — a write ran past pool_capacity
    snap_dropped: jax.Array  # () bool — a snapshot was attempted (and
                             # dropped) on a chain already at max_chain

    @property
    def active(self) -> jax.Array:
        return self.length - 1


def create(spec: ChainSpec, *, scalable: bool = True) -> Chain:
    """A fresh virtual disk: chain of length 1 (a single active volume)."""
    return Chain(
        spec=spec,
        scalable=scalable,
        l1=jnp.zeros((spec.max_chain, spec.n_l1), jnp.uint32),
        l2=fmt.empty_entries((spec.max_chain, spec.n_pages)),
        pool=jnp.zeros((spec.pool_capacity, spec.page_size), spec.dtype),
        pool_cursor=jnp.zeros((), jnp.int32),
        length=jnp.ones((), jnp.int32),
        overflow=jnp.zeros((), bool),
        snap_dropped=jnp.zeros((), bool),
    )


def write_tables(l1: jax.Array, l2: jax.Array, active: jax.Array,
                 page_ids: jax.Array, rows: jax.Array, *, scalable,
                 l2_per_table: int, mask=None):
    """Stamp COW entries for ``rows`` into the active volume's L1/L2.

    Shared by the single-chain ``write`` and the fleet's batched write
    (which vmaps it over the tenant axis). ``active`` may be a traced
    scalar; ``scalable`` a python bool or a traced boolean scalar;
    ``mask`` (B,) bool suppresses updates where False (inactive tenants).
    Returns the updated ``(l1, l2)``.
    """
    bsz = page_ids.shape[0]
    page_ids = page_ids.astype(jnp.int32)
    entries = fmt.pack_entry(
        rows,
        jnp.broadcast_to(active.astype(jnp.uint32), (bsz,)),
        allocated=True,
        bfi_valid=scalable,
    )
    n_pages = l2.shape[-2]
    n_l1 = l1.shape[-1]
    mask = jnp.broadcast_to(
        jnp.asarray(True if mask is None else mask, bool), (bsz,)
    )
    # masked-out entries scatter to the OOB-high drop sentinel (negative
    # indices would wrap); surviving indices are unique per the write
    # contract, so no duplicate-index ordering hazard remains
    l2 = l2.at[active, jnp.where(mask, page_ids, n_pages)].set(
        entries, mode="drop"
    )
    tables = jnp.where(mask, page_ids // l2_per_table, n_l1)
    l1 = l1.at[active, tables].set(jnp.uint32(1), mode="drop")
    return l1, l2


def copy_forward_tables(l1: jax.Array, l2: jax.Array, new: jax.Array):
    """sQEMU §5.4 snapshot copy-forward: duplicate the previous active
    volume's entire L1/L2 set into layer ``new`` (a traced index).

    The new volume then indexes the whole chain, keeping direct access
    O(1). Shared by ``snapshot`` and the fleet's per-tenant snapshot.
    """
    prev_l1 = jax.lax.dynamic_index_in_dim(l1, new - 1, 0)
    prev_l2 = jax.lax.dynamic_index_in_dim(l2, new - 1, 0)
    l1 = jax.lax.dynamic_update_index_in_dim(l1, prev_l1, new, 0)
    l2 = jax.lax.dynamic_update_index_in_dim(l2, prev_l2, new, 0)
    return l1, l2


@jax.jit
def write(chain: Chain, page_ids: jax.Array, data: jax.Array) -> Chain:
    """COW write of whole pages to the active volume.

    ``page_ids``: (B,) int32 logical page indices — must be unique within
    the batch (cluster-aligned whole-page writes, like the Qcow2 driver's
    cluster granularity). ``data``: (B, page_size).

    Writes always allocate fresh pool rows and update only the active
    volume's L1/L2 — backing files are immutable (Qcow2 COW semantics).
    """
    spec = chain.spec
    bsz = page_ids.shape[0]
    rows = chain.pool_cursor + jnp.arange(bsz, dtype=jnp.int32)
    ok = rows < spec.pool_capacity
    overflow = chain.overflow | ~jnp.all(ok)
    # overflow rows are dropped (OOB-high scatter sentinel), never clamped
    # onto the last pool row — same contract as fleet.write
    pool = chain.pool.at[jnp.where(ok, rows, spec.pool_capacity)].set(
        data.astype(spec.dtype), mode="drop"
    )

    l1, l2 = write_tables(
        chain.l1, chain.l2, chain.length - 1, page_ids,
        jnp.where(ok, rows, 0),
        scalable=chain.scalable, l2_per_table=spec.l2_per_table, mask=ok,
    )
    return dataclasses.replace(
        chain,
        l1=l1,
        l2=l2,
        pool=pool,
        pool_cursor=chain.pool_cursor + jnp.sum(ok, dtype=jnp.int32),
        overflow=overflow,
    )


@partial(jax.jit, static_argnames=("scalable",))
def _snapshot_impl(chain: Chain, scalable: bool) -> Chain:
    # a full chain cannot snapshot: cap length and flag overflow (same
    # semantics as fleet.snapshot), else later writes scatter out of bounds
    can = chain.length < chain.spec.max_chain
    if scalable:
        c1, c2 = copy_forward_tables(chain.l1, chain.l2, chain.length)
        l1 = jnp.where(can, c1, chain.l1)
        l2 = jnp.where(can, c2, chain.l2)
    else:
        # vanilla Qcow2: the new active volume starts with no tables at all
        # (layers above `length` are still all-zeros by construction).
        l1, l2 = chain.l1, chain.l2
    return dataclasses.replace(
        chain, l1=l1, l2=l2,
        length=chain.length + can.astype(jnp.int32),
        snap_dropped=chain.snap_dropped | ~can,
    )


def snapshot(chain: Chain, *, scalable: bool | None = None) -> Chain:
    """Freeze the active volume as a backing file; open a new active volume.

    ``scalable=None`` follows the chain's format flag. Passing an explicit
    value models mixed deployments (e.g. a vanilla tool snapshotting a
    scalable image: the copy-forward is skipped, and readers of pages
    written afterwards simply fall back to the chain walk — backward
    compatibility per paper §5.1).
    """
    if scalable is None:
        scalable = chain.scalable
    return _snapshot_impl(chain, scalable)


def snapshot_cost_model(spec: ChainSpec) -> dict:
    """Paper Eq. 2: per-snapshot metadata overhead of the scalable format.

    S_sq = S_vq + disk_size / cluster_size * l2_entry_size
    """
    l2_entry_size = fmt.ENTRY_WORDS * 4
    extra = spec.n_pages * l2_entry_size + spec.n_l1 * 4
    return dict(
        vanilla_bytes=spec.n_l1 * 4,     # header+L1 only (refcounts elided)
        scalable_bytes=spec.n_l1 * 4 + extra,
        extra_bytes=extra,
    )


def plan_merge(l2: jax.Array, merge_upto: int):
    """Owner-resolve layers ``[0, merge_upto]`` of one table stack.

    ``l2``: (C, n_pages, 2). Returns ``(merged (n_pages, 2), found
    (n_pages,) bool)`` — per page, the entry of the topmost merged layer
    that has it allocated. Table-level helper shared by ``stream`` and the
    fleet's ``stream_tenants``.
    """
    k = merge_upto + 1
    sub = l2[:k]                                         # (k, n_pages, 2)
    alloc = fmt.entry_allocated(sub)                     # (k, n_pages)
    idx = jnp.arange(k, dtype=jnp.int32)[:, None]
    owner = jnp.max(jnp.where(alloc, idx, -1), axis=0)   # (n_pages,)
    found = owner >= 0
    safe_owner = jnp.maximum(owner, 0)
    merged = jnp.take_along_axis(sub, safe_owner[None, :, None], axis=0)[0]
    return merged, found


def merge_tables(l1: jax.Array, l2: jax.Array, length: int, merge_upto: int,
                 *, scalable, ptr_override: jax.Array | None = None,
                 plan=None):
    """Merge layers ``[0, merge_upto]`` of one table stack into one base.

    The table-level core of streaming, shared by ``stream`` and the
    fleet's ``stream_tenants`` (same pattern as the ``*_tables``
    resolvers, so chain and fleet semantics cannot drift).

    ``l1``: (C, n_l1); ``l2``: (C, n_pages, 2); ``length`` is the concrete
    chain length (host int — maintenance ops are not jitted).
    ``ptr_override``: optional (n_pages,) replacement pool rows for merged
    pages (the data-movement path); scalable upper-layer entries that
    reference a merged owner are rewritten to match. ``plan``: an already
    computed ``plan_merge(l2, merge_upto)`` result, so a caller that
    needed the plan to build ``ptr_override`` does not pay the owner
    scan twice.

    Renumbering: the merged base takes bfi 0; upper layer ``s`` becomes
    ``s - merge_upto``, and upper entries pointing below the merge point
    collapse onto bfi 0. Returns ``(l1', l2', new_length)``.
    """
    max_chain, n_pages = l2.shape[0], l2.shape[1]
    n_l1 = l1.shape[1]
    k = merge_upto + 1
    merged, found = plan_merge(l2, merge_upto) if plan is None else plan
    ptr = (fmt.entry_ptr(merged) if ptr_override is None
           else jnp.asarray(ptr_override, jnp.uint32))

    merged_entries = fmt.pack_entry(
        ptr, jnp.zeros_like(ptr), allocated=found, bfi_valid=scalable,
        zero=fmt.entry_zero(merged),
    )

    n_upper = length - k
    upper_l2 = l2[k:k + n_upper]
    upper_l1 = l1[k:k + n_upper]
    old_bfi = fmt.entry_bfi(upper_l2).astype(jnp.int32)
    new_bfi = jnp.maximum(old_bfi - merge_upto, 0)
    upper_alloc = fmt.entry_allocated(upper_l2)
    upper_ptr = fmt.entry_ptr(upper_l2)
    if ptr_override is not None:
        # Upper entries whose owner was merged must point at the new rows.
        # Only bfi-valid entries reference an ancestor's row; a vanilla
        # (bfi-invalid) allocated entry owns its page outright, and its
        # bfi field of 0 must not be mistaken for "points below".
        points_below = (upper_alloc & fmt.entry_bfi_valid(upper_l2)
                        & (old_bfi <= merge_upto))
        upper_ptr = jnp.where(points_below, ptr[None, :], upper_ptr)
    upper_l2 = fmt.pack_entry(
        upper_ptr, new_bfi, allocated=upper_alloc,
        bfi_valid=fmt.entry_bfi_valid(upper_l2),
        zero=fmt.entry_zero(upper_l2),
    )

    new_len = 1 + n_upper
    out_l2 = fmt.empty_entries((max_chain, n_pages))
    out_l2 = out_l2.at[0].set(merged_entries)
    out_l2 = out_l2.at[1:1 + n_upper].set(upper_l2)
    out_l1 = jnp.zeros((max_chain, n_l1), jnp.uint32)
    out_l1 = out_l1.at[0].set(jnp.max(l1[:k], axis=0))
    out_l1 = out_l1.at[1:1 + n_upper].set(upper_l1)
    return out_l1, out_l2, new_len


def stream(chain: Chain, merge_upto: int, *, copy_data: bool = True) -> Chain:
    """Compact layers ``[0, merge_upto]`` into a single base layer.

    Host-side maintenance op (uses the concrete chain length; not jittable).
    ``copy_data=True`` rewrites merged pages into fresh pool rows, modelling
    the real streaming job's data movement (the source of the paper's
    observed 100x guest-latency hit during streaming); ``False`` merges
    metadata only (pool rows are immutable and global, so this is safe).

    On pool exhaustion the copy is dropped and the merge degrades to
    metadata-only, flagging ``overflow`` — the write path's contract — so
    a background scheduler can skip, compact, and retry instead of
    unwinding a mid-operation ``RuntimeError``. The chain stays consistent
    either way.
    """
    spec = chain.spec
    length = int(chain.length)
    if not (0 <= merge_upto < length - 1):
        raise ValueError("can only merge strictly below the active volume")

    cursor = chain.pool_cursor
    pool = chain.pool
    overflow = chain.overflow
    ptr_override = None
    plan = None
    if copy_data:
        plan = merged, found = plan_merge(chain.l2, merge_upto)
        ptr = fmt.entry_ptr(merged)
        n_live = int(jnp.sum(found))
        if int(cursor) + n_live > spec.pool_capacity:
            overflow = jnp.ones((), bool)
        elif n_live:
            # Rewrite surviving merged pages to fresh rows (data movement).
            live_pages = jnp.nonzero(found, size=spec.n_pages, fill_value=0)[0]
            live = live_pages[:n_live]
            src_rows = ptr[live].astype(jnp.int32)
            dst_rows = int(cursor) + jnp.arange(n_live, dtype=jnp.int32)
            pool = pool.at[dst_rows].set(pool[src_rows])
            ptr_override = ptr.at[live].set(dst_rows.astype(jnp.uint32))
            cursor = cursor + n_live

    l1, l2, new_len = merge_tables(
        chain.l1, chain.l2, length, merge_upto,
        scalable=chain.scalable, ptr_override=ptr_override, plan=plan,
    )
    # the dropped-snapshot flag is resolved only if streaming actually made
    # room (merge_upto=0 merges layer 0 into itself and shortens nothing)
    return dataclasses.replace(
        chain,
        l1=l1,
        l2=l2,
        pool=pool,
        pool_cursor=jnp.asarray(cursor, jnp.int32),
        length=jnp.asarray(new_len, jnp.int32),
        overflow=overflow,
        snap_dropped=chain.snap_dropped & (new_len >= spec.max_chain),
    )


def compact_pool(chain: Chain) -> Chain:
    """Garbage-collect the page pool: keep only rows referenced by live
    L2 entries, remap pointers, reset the allocation cursor.

    Host-side maintenance op (like streaming). COW stores leak pool rows
    whenever a page is overwritten or a chain is streamed; the provider's
    background GC reclaims them. Content of every read is unchanged
    (property-tested).
    """
    import numpy as np

    spec = chain.spec
    length = int(chain.length)
    entries = chain.l2[:length]                       # (L, n_pages, 2)
    alloc = np.asarray(fmt.entry_allocated(entries))
    rows = np.asarray(fmt.entry_ptr(entries))
    used = np.unique(rows[alloc])
    lut = np.zeros(spec.pool_capacity, np.uint32)
    lut[used] = np.arange(len(used), dtype=np.uint32)

    new_pool = jnp.zeros_like(chain.pool)
    if len(used):
        new_pool = new_pool.at[: len(used)].set(
            chain.pool[jnp.asarray(used, jnp.int32)]
        )
    new_ptr = jnp.asarray(lut[rows], jnp.uint32)
    new_entries = fmt.pack_entry(
        new_ptr,
        fmt.entry_bfi(entries),
        allocated=jnp.asarray(alloc),
        bfi_valid=fmt.entry_bfi_valid(entries),
        zero=fmt.entry_zero(entries),
    )
    l2 = chain.l2.at[:length].set(new_entries)
    # GC resolves pool overflow; snap_dropped is chain exhaustion and is
    # untouched (compaction frees rows, it doesn't shorten the chain)
    return dataclasses.replace(
        chain,
        l2=l2,
        pool=new_pool,
        pool_cursor=jnp.asarray(len(used), jnp.int32),
        overflow=jnp.zeros((), bool),
    )


def convert_to_scalable(chain: Chain) -> Chain:
    """Offline conversion of a vanilla-format chain to the scalable format.

    Models the paper's image-conversion path for adoption (§5.1): resolves
    every page through the chain walk once and writes a fully flattened,
    bfi-stamped L1/L2 set into the active volume.
    """
    from repro.core import resolve  # local import to avoid a cycle

    spec = chain.spec
    res = resolve.resolve_vanilla(chain, jnp.arange(spec.n_pages, dtype=jnp.int32))
    entries = fmt.pack_entry(
        res.ptr, res.owner.astype(jnp.uint32),
        allocated=res.found, bfi_valid=True, zero=res.zero, cold=res.cold,
    )
    active = int(chain.length) - 1
    l2 = chain.l2.at[active].set(entries)
    table_alloc = jnp.max(
        res.found.reshape(spec.n_l1, spec.l2_per_table), axis=1
    ).astype(jnp.uint32)
    l1 = chain.l1.at[active].set(table_alloc)
    return dataclasses.replace(chain, l1=l1, l2=l2, scalable=True)
