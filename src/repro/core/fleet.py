"""ChainFleet: N independent snapshot chains over one shared page pool.

The paper's evaluation (and ``chain.py``) operates on one chain at a time,
but the cloud trace in §3 is thousands of tenant disks hitting a shared
storage backend concurrently. ``ChainFleet`` is the fleet-granularity
substrate: a *stacked* representation of ``n_tenants`` chains —

* per-tenant L1/L2 index stacks ``(T, max_chain, ...)`` and per-tenant
  chain ``length`` / ``scalable`` / ``overflow`` state;
* **one global page pool** shared by every tenant (the single-HBM analogue
  of the provider's backend), carved into fixed-size *lease quanta* by a
  fleet-level allocator: a tenant acquires whole quanta on demand, and its
  n-th allocated row lives at ``lease_index[t, n // Q] * Q + n % Q``.
  Leases are disjoint, so concurrent tenant writes never collide and a
  tenant exhausting the pool flags only its own ``overflow``.

Every data-path operation is batched across the fleet inside a single jit:

* ``resolve_{vanilla,direct,auto}`` vmap the table-level resolvers from
  ``core.resolve`` over the tenant axis — one dispatch for the whole
  fleet instead of T dispatches (and T re-traces) of the per-chain path;
  the ``"pallas_vanilla"``/``"pallas_direct"`` resolver methods run the
  stacked (T, C, P) Pallas kernels of ``kernels/chain_resolve`` instead
  (compiled on TPU, interpret mode elsewhere), and ``method="auto"``
  picks the kernel path whenever the layout qualifies (page axis already
  a 128-lane multiple — see ``docs/kernels.md``);
* ``write`` performs fleet-wide COW: lease acquisition, pool scatter and
  per-tenant L1/L2 stamping for all tenants at once, with an optional
  per-tenant mask for partial batches;
* ``snapshot`` snapshots any subset of tenants, honouring each tenant's
  format flag (mixed scalable/vanilla fleets are first-class: ``scalable``
  is a traced per-tenant array, not a static).

The single-chain paths in ``chain.py``/``resolve.py`` share the same
helpers (``write_tables``, ``copy_forward_tables``, ``*_tables``
resolvers), so fleet and chain semantics cannot drift apart; the test
suite additionally property-checks per-tenant fleet resolution against a
python loop over single chains.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain as chain_lib
from repro.core import format as fmt
from repro.core import resolve as resolve_lib
from repro.core.chain import Chain, ChainSpec
from repro.kernels.cow_gather import ops as cow_ops


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Static geometry of a fleet (hashable; safe as a jit static arg)."""

    n_tenants: int
    n_pages: int
    page_size: int
    max_chain: int
    pool_capacity: int       # global pool rows shared by the whole fleet
    lease_quantum: int = 64  # pool rows acquired per lease
    l2_per_table: int = 64
    slice_len: int = 16
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.n_tenants < 1:
            raise ValueError("n_tenants must be >= 1")
        if self.pool_capacity % self.lease_quantum != 0:
            raise ValueError("pool_capacity must be a multiple of lease_quantum")
        # delegate the per-chain validations (bit widths, divisibility)
        self.chain_spec()

    @property
    def n_quanta(self) -> int:
        return self.pool_capacity // self.lease_quantum

    @property
    def n_l1(self) -> int:
        return self.n_pages // self.l2_per_table

    def chain_spec(self) -> ChainSpec:
        """The per-tenant view: same geometry, the shared (global) pool."""
        return ChainSpec(
            n_pages=self.n_pages,
            page_size=self.page_size,
            max_chain=self.max_chain,
            pool_capacity=self.pool_capacity,
            l2_per_table=self.l2_per_table,
            slice_len=self.slice_len,
            dtype=self.dtype,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ChainFleet:
    spec: FleetSpec = dataclasses.field(metadata=dict(static=True))
    l1: jax.Array           # (T, max_chain, n_l1) uint32
    l2: jax.Array           # (T, max_chain, n_pages, 2) uint32
    pool: jax.Array         # (pool_capacity, page_size) dtype — shared
    lease_owner: jax.Array  # (n_quanta,) int32 — owning tenant, -1 = free
    lease_index: jax.Array  # (T, n_quanta) int32 — quantum ids in lease order
    lease_count: jax.Array  # (T,) int32 — leases held per tenant
    alloc_count: jax.Array  # (T,) int32 — pool rows allocated per tenant
    length: jax.Array       # (T,) int32 — chain length per tenant
    scalable: jax.Array     # (T,) bool — per-tenant format flag
    overflow: jax.Array     # (T,) bool — per-tenant pool-lease exhaustion
    snap_dropped: jax.Array  # (T,) bool — snapshot attempted at max_chain
    cold_count: jax.Array   # (T,) int32 — host-tier rows held per tenant
                            # (maintained by demote/promote_tenants)

    @property
    def n_tenants(self) -> int:
        return self.spec.n_tenants

    @property
    def active(self) -> jax.Array:
        return self.length - 1


def create(spec: FleetSpec, *, scalable=True) -> ChainFleet:
    """A fresh fleet: every tenant is a chain of length 1 with no leases.

    ``scalable`` may be a python bool (uniform fleet) or a (T,) bool array
    (mixed deployment: some tenants on the vanilla format).
    """
    t = spec.n_tenants
    scal = jnp.broadcast_to(jnp.asarray(scalable, bool), (t,))
    return ChainFleet(
        spec=spec,
        l1=jnp.zeros((t, spec.max_chain, spec.n_l1), jnp.uint32),
        l2=fmt.empty_entries((t, spec.max_chain, spec.n_pages)),
        pool=jnp.zeros((spec.pool_capacity, spec.page_size), spec.dtype),
        lease_owner=jnp.full((spec.n_quanta,), -1, jnp.int32),
        lease_index=jnp.full((t, spec.n_quanta), -1, jnp.int32),
        lease_count=jnp.zeros((t,), jnp.int32),
        alloc_count=jnp.zeros((t,), jnp.int32),
        length=jnp.ones((t,), jnp.int32),
        scalable=scal,
        overflow=jnp.zeros((t,), bool),
        snap_dropped=jnp.zeros((t,), bool),
        cold_count=jnp.zeros((t,), jnp.int32),
    )


# -- fleet allocator ---------------------------------------------------------


def _acquire_leases(fleet: ChainFleet, rows_needed: jax.Array):
    """Grant each tenant enough fresh quanta to cover ``rows_needed`` more
    rows. Fully vectorized: free quanta are ranked once and handed out in
    tenant order via an exclusive cumsum. Returns the updated lease state
    plus a per-tenant "went short" flag.
    """
    spec = fleet.spec
    q = spec.lease_quantum
    nq = spec.n_quanta
    t = spec.n_tenants

    new_total = fleet.alloc_count + rows_needed
    want_leases = jnp.maximum(-(-new_total // q) - fleet.lease_count, 0)

    free = fleet.lease_owner < 0
    free_ids = jnp.nonzero(free, size=nq, fill_value=-1)[0]     # (nq,)
    n_free = jnp.sum(free)

    start = jnp.cumsum(want_leases) - want_leases               # (T,) exclusive
    j = jnp.arange(nq, dtype=jnp.int32)[None, :]                # (1, nq)
    want = j < want_leases[:, None]                             # (T, nq)
    src = start[:, None] + j
    ok = want & (src < n_free)
    grant = jnp.where(ok, free_ids[jnp.clip(src, 0, nq - 1)], -1)  # (T, nq)
    # compare against want_leases itself, not the (T, nq) grid: one batch can
    # want more quanta than the whole pool holds (want_leases > nq)
    short = jnp.sum(ok, axis=1) < want_leases

    tids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None], (t, nq))
    # drop-sentinel must be out-of-bounds HIGH: negative indices wrap in JAX
    # scatters even under mode="drop".
    scatter_idx = jnp.where(ok, grant, nq)
    lease_owner = fleet.lease_owner.at[scatter_idx.reshape(-1)].set(
        tids.reshape(-1), mode="drop"
    )

    def stitch(li, cnt, grant_t, ok_t):
        # granted positions are distinct and < nq (total leases can't exceed
        # n_quanta); non-grants scatter to the OOB-high drop sentinel, so no
        # duplicate-index collisions can clobber a real grant
        pos = jnp.where(ok_t, cnt + jnp.arange(nq, dtype=jnp.int32), nq)
        return li.at[pos].set(grant_t, mode="drop")

    lease_index = jax.vmap(stitch)(fleet.lease_index, fleet.lease_count,
                                   grant, ok)
    lease_count = fleet.lease_count + jnp.sum(ok, axis=1)
    return lease_owner, lease_index, lease_count, short


def _rows_for(spec: FleetSpec, lease_index: jax.Array,
              alloc_count: jax.Array, bsz: int):
    """Global pool rows for each tenant's next ``bsz`` allocations.

    Returns ``(rows (T, B) int32, leased (T, B) bool)`` — ``rows`` is -1
    where the tenant holds no lease for that slot.
    """
    q = spec.lease_quantum
    nq = spec.n_quanta
    local = alloc_count[:, None] + jnp.arange(bsz, dtype=jnp.int32)[None, :]
    slot = local // q
    # bound the gather: JAX clamps OOB indices to nq-1, which would alias
    # post-exhaustion writes onto the final quantum's (immutable) rows
    quantum = jnp.take_along_axis(lease_index, jnp.minimum(slot, nq - 1),
                                  axis=1)
    leased = (quantum >= 0) & (slot < nq)
    rows = jnp.where(leased, quantum * q + local % q, -1)
    return rows, leased


# -- batched data path -------------------------------------------------------


@jax.jit
def write(fleet: ChainFleet, page_ids: jax.Array, data: jax.Array,
          mask: jax.Array | None = None) -> ChainFleet:
    """Fleet-wide COW write: one batch of pages per tenant, one dispatch.

    ``page_ids``: (T, B) int32, unique within each tenant's batch;
    ``data``: (T, B, page_size); ``mask``: optional (T,) bool selecting
    which tenants participate (inactive tenants are untouched).

    Semantics per tenant match ``chain.write``: fresh pool rows, active
    volume's L1/L2 stamped, backing files immutable. Rows come from the
    tenant's leased quanta; the allocator grants new quanta on demand and
    flags ``overflow`` for tenants the pool cannot serve (their excess
    pages are dropped — never written into another tenant's lease).
    """
    spec = fleet.spec
    t, bsz = page_ids.shape
    page_ids = page_ids.astype(jnp.int32)
    tmask = (jnp.ones((t,), bool) if mask is None
             else jnp.asarray(mask, bool))
    need = jnp.where(tmask, bsz, 0).astype(jnp.int32)

    lease_owner, lease_index, lease_count, short = _acquire_leases(fleet, need)
    rows, leased = _rows_for(spec, lease_index, fleet.alloc_count, bsz)
    valid = leased & tmask[:, None]                       # (T, B)

    # drop-sentinel is out-of-bounds HIGH (negative indices wrap in scatters)
    flat_rows = jnp.where(valid, rows, spec.pool_capacity).reshape(-1)
    pool = fleet.pool.at[flat_rows].set(
        data.astype(spec.dtype).reshape(t * bsz, -1), mode="drop"
    )

    stamp = partial(chain_lib.write_tables, l2_per_table=spec.l2_per_table)
    l1, l2 = jax.vmap(
        lambda l1_t, l2_t, act, pids, rows_t, scal, m:
        stamp(l1_t, l2_t, act, pids, jnp.maximum(rows_t, 0),
              scalable=scal, mask=m)
    )(fleet.l1, fleet.l2, fleet.length - 1, page_ids, rows,
      fleet.scalable, valid)

    return dataclasses.replace(
        fleet,
        l1=l1,
        l2=l2,
        pool=pool,
        lease_owner=lease_owner,
        lease_index=lease_index,
        lease_count=lease_count,
        alloc_count=fleet.alloc_count + jnp.sum(valid, axis=1, dtype=jnp.int32),
        overflow=fleet.overflow | (short & tmask),
    )


@jax.jit
def snapshot(fleet: ChainFleet, mask: jax.Array | None = None,
             scalable: jax.Array | None = None) -> ChainFleet:
    """Per-tenant snapshot: freeze each selected tenant's active volume.

    ``mask``: optional (T,) bool — which tenants snapshot this step.
    ``scalable``: optional override (python bool or (T,) bool), as in
    ``chain.snapshot`` — models a vanilla tool snapshotting a scalable
    image. Defaults to each tenant's own format flag. Tenants already at
    ``max_chain`` are skipped and flagged ``snap_dropped``.
    """
    spec = fleet.spec
    t = spec.n_tenants
    tmask = (jnp.ones((t,), bool) if mask is None
             else jnp.asarray(mask, bool))
    scal = (fleet.scalable if scalable is None
            else jnp.broadcast_to(jnp.asarray(scalable, bool), (t,)))

    can = tmask & (fleet.length < spec.max_chain)

    def snap_one(l1_t, l2_t, len_t, do_copy):
        c1, c2 = chain_lib.copy_forward_tables(l1_t, l2_t, len_t)
        return (jnp.where(do_copy, c1, l1_t), jnp.where(do_copy, c2, l2_t))

    l1, l2 = jax.vmap(snap_one)(fleet.l1, fleet.l2, fleet.length, can & scal)
    return dataclasses.replace(
        fleet,
        l1=l1,
        l2=l2,
        length=fleet.length + can.astype(jnp.int32),
        snap_dropped=fleet.snap_dropped | (tmask & ~can),
    )


def _batched_resolver(name: str):
    fn = resolve_lib.get_table_resolver(name)

    @jax.jit
    def batched(fleet: ChainFleet, page_ids: jax.Array):
        return jax.vmap(fn)(fleet.l2, fleet.length,
                            page_ids.astype(jnp.int32))

    return batched


#: Batched resolvers: page_ids (T, B) → ResolveResult of (T, B) leaves.
resolve_vanilla = _batched_resolver("vanilla")
resolve_direct = _batched_resolver("direct")


def fused_layout_ok(n_pages: int) -> bool:
    """The lane-alignment rule the kernel plane's auto-selection shares:
    a stacked index whose page axis is a 128-lane multiple tiles the
    Pallas kernels with no padding. ``resolve_auto`` uses it to pick the
    kernel resolvers, and the serving engine uses it to pick the fused
    chain-resolve attention path (``Engine(decode_path="auto")``)."""
    return n_pages % 128 == 0


def _kernel_layout_ok(spec: FleetSpec) -> bool:
    """Static (trace-time) rule for ``method="auto"``: use the Pallas
    kernels only when the page axis is already a 128-lane multiple, so the
    stacked tables tile with no padding. Explicit ``pallas_*`` methods pad
    and run the kernel regardless."""
    return fused_layout_ok(spec.n_pages)


@jax.jit
def resolve_pallas_vanilla(fleet: ChainFleet, page_ids: jax.Array):
    """Stacked-kernel chain walk; bit-identical to ``resolve_vanilla``."""
    return resolve_lib.resolve_vanilla_stacked(fleet.l2, fleet.length,
                                               page_ids)


@jax.jit
def resolve_pallas_direct(fleet: ChainFleet, page_ids: jax.Array):
    """Stacked-kernel direct access; bit-identical to ``resolve_direct``."""
    return resolve_lib.resolve_direct_stacked(fleet.l2, fleet.length,
                                              page_ids)


@jax.jit
def resolve_auto(fleet: ChainFleet, page_ids: jax.Array):
    """Mixed-image resolution (direct where trusted, walk otherwise).

    Implementation is chosen statically at trace time: the stacked Pallas
    kernels when the layout qualifies (``_kernel_layout_ok``), the
    vmapped jnp gather otherwise. Both produce bit-identical results —
    only the data plane differs. Off-TPU the kernels run in interpret
    mode (so CI exercises them), which is slower than the vmapped gather;
    latency-sensitive CPU callers with lane-aligned layouts should pass
    an explicit jnp method (``"vanilla"``/``"direct"``/``"gather"``).
    """
    if _kernel_layout_ok(fleet.spec):
        return resolve_lib.resolve_auto_stacked(fleet.l2, fleet.length,
                                                page_ids)
    return jax.vmap(resolve_lib.get_table_resolver("auto"))(
        fleet.l2, fleet.length, page_ids.astype(jnp.int32)
    )


_RESOLVERS = {
    "vanilla": resolve_vanilla,
    # "gather" names the implementation rather than the strategy: the
    # vmapped-jnp walk, the baseline the benchmarks/tests compare the
    # Pallas kernels against
    "gather": resolve_vanilla,
    "direct": resolve_direct,
    "auto": resolve_auto,
    "pallas_vanilla": resolve_pallas_vanilla,
    "pallas_direct": resolve_pallas_direct,
}


def get_resolver(name: str):
    """Look up a batched fleet resolver by method name.

    Methods: ``"vanilla"`` (alias ``"gather"``) — vmapped O(chain) walk;
    ``"direct"`` — vmapped O(1) lookup; ``"pallas_vanilla"`` /
    ``"pallas_direct"`` — the same strategies as stacked Pallas kernels;
    ``"auto"`` — per-page direct-where-trusted semantics, kernel-backed
    when the layout qualifies. Every method returns a resolver with
    signature ``(fleet, page_ids (T, B)) -> ResolveResult`` of (T, B)
    leaves. Raises ``ValueError`` for unknown names.
    """
    return resolve_lib.lookup_resolver(_RESOLVERS, name)


def _uses_kernels(spec: FleetSpec, method: str) -> bool:
    return (method in ("pallas_vanilla", "pallas_direct")
            or (method == "auto" and _kernel_layout_ok(spec)))


@partial(jax.jit, static_argnames=("method",))
def read(fleet: ChainFleet, page_ids: jax.Array, *, method: str = "auto"):
    """Batched whole-page read across the fleet.

    Args:
        fleet: the fleet state (untouched — reads are pure).
        page_ids: (T, B) int32 logical page indices, one batch per tenant.
        method: resolver method (see ``get_resolver``). The default
            ``"auto"`` resolves each page direct-where-trusted and uses
            the Pallas kernel data plane when the layout qualifies.

    Returns:
        ``(data, result)`` where ``data`` is (T, B, page_size) — the pool
        is global, so one gather serves every tenant — and ``result`` is
        the ``ResolveResult`` of (T, B) leaves the gather consumed.
        Unallocated or ZERO pages read as zeros, exactly as
        ``store.read``. Kernel methods gather through the stacked Pallas
        gather of ``kernels/cow_gather``; jnp methods use the shared
        ``store.gather_pages`` helper. Both are bit-identical.
    """
    from repro.core import store  # local import: store is the public API layer

    res = get_resolver(method)(fleet, page_ids)
    if _uses_kernels(fleet.spec, method):
        # cold hits address the host tier — mask them like ZERO clusters
        # (read_tiered fills them from the TieredStore afterwards)
        ok = res.found & ~res.zero & ~res.cold
        rows = jnp.where(ok, res.ptr, 0).astype(jnp.int32)
        return cow_ops.gather_fleet(fleet.pool, rows, ok), res
    return store.gather_pages(fleet.pool, res), res


def materialize(fleet: ChainFleet, *, method: str = "auto") -> jax.Array:
    """Read every tenant's full virtual disk: (T, n_pages, page_size).

    ``method`` is any ``get_resolver`` name; the fleet-wide 'dd' op.
    """
    spec = fleet.spec
    ids = jnp.broadcast_to(
        jnp.arange(spec.n_pages, dtype=jnp.int32)[None, :],
        (spec.n_tenants, spec.n_pages),
    )
    data, _ = read(fleet, ids, method=method)
    return data


# -- tenant lifecycle: attach / clone / fork / free / stamp ------------------
#
# The serving plane (``kvcache.paged``) runs each live sequence as a fleet
# tenant: these are the tenancy primitives it is built on. ``free_tenant``
# is also the maintenance plane's "tenant deletion" op (a retired disk's
# whole lease set returns to the allocator in one call).


def _tenant_sel(n_tenants: int, tenants) -> np.ndarray:
    """Normalize an int / id-list / bool-mask tenant selector to a mask."""
    t = np.asarray(tenants)
    if t.dtype == bool:
        return np.broadcast_to(t, (n_tenants,))
    sel = np.zeros(n_tenants, bool)
    if t.size:                     # an empty id list selects nothing
        sel[np.atleast_1d(t).astype(np.int64)] = True
    return sel


def free_tenant(fleet: ChainFleet, tenants, *, store=None,
                registry=None) -> ChainFleet:
    """Retire tenants wholesale: reset their chains and return each one's
    *entire* lease set to the allocator free list in one call.

    Unlike ``_reclaim`` — which repacks live rows and releases only the
    quanta past the packed prefix — this drops everything the tenant
    holds: every leased quantum goes back to the free list at once, the
    L1/L2 stacks reset to an empty length-1 chain, and the pressure flags
    clear. Host-side, like the other maintenance ops. The serving engine
    uses it for ``finish_request`` (a retired sequence's tenant slot).

    Args:
        fleet: the fleet state (returned updated, never mutated).
        tenants: an int tenant id, a sequence of ids, or a (T,) bool mask.
        store: the ``TieredStore`` holding any demoted pages of the freed
            tenants. Their host rows are returned to the store's free
            list here — a freed tenant must leave no orphaned host pages.
            Required iff a selected tenant holds cold rows.
        registry: the ``GoldenRegistry``, when the fleet runs one.
            Freeing a registered golden *owner* is refused (live or not,
            its rows may be pinned — ``unregister`` first); freeing a
            golden *fork* releases its pins on the shared base here, so
            callers cannot leak refcounts.

    Returns:
        The updated ``ChainFleet``. Pool rows formerly referenced by the
        freed tenants are garbage until their quanta are re-leased (rows
        are never zeroed, exactly as after ``_reclaim``).
    """
    spec = fleet.spec
    sel = _tenant_sel(spec.n_tenants, tenants)
    idx = np.flatnonzero(sel)
    if idx.size == 0:
        return fleet
    if registry is not None:
        owners = [int(t) for t in idx if registry.is_golden_owner(int(t))]
        if owners:
            raise ValueError(
                f"tenants {owners} are registered golden bases; "
                "unregister them before freeing (forks may pin their rows)"
            )
        for t in idx:
            if registry.is_fork(int(t)):
                registry.release(int(t))
    cold_held = np.asarray(fleet.cold_count)[idx]
    if np.any(cold_held > 0):
        if store is None:
            raise ValueError(
                f"tenants {idx[cold_held > 0].tolist()} hold host-tier "
                "rows; pass the TieredStore so free_tenant can release "
                "them (orphaned host pages otherwise)"
            )
        # sweep the freed tenants' L2 stacks for COLD entries and hand
        # their host rows back to the cold tier's free list
        for t in idx[cold_held > 0]:
            entries = np.asarray(fleet.l2[int(t), : int(fleet.length[int(t)])])
            coldm = (np.asarray(fmt.entry_cold(entries))
                     & np.asarray(fmt.entry_allocated(entries))
                     & ~np.asarray(fmt.entry_zero(entries)))
            host_rows = np.unique(
                np.asarray(fmt.entry_ptr(entries))[coldm].astype(np.int64)
            )
            store.free(host_rows)
    lease_owner = np.asarray(fleet.lease_owner).copy()
    lease_owner[np.isin(lease_owner, idx)] = -1
    lease_index = np.asarray(fleet.lease_index).copy()
    lease_index[idx] = -1
    rows = jnp.asarray(idx, jnp.int32)
    zero = lambda a: a.at[rows].set(0)
    return dataclasses.replace(
        fleet,
        l1=zero(fleet.l1),
        l2=zero(fleet.l2),
        lease_owner=jnp.asarray(lease_owner, jnp.int32),
        lease_index=jnp.asarray(lease_index, jnp.int32),
        lease_count=zero(fleet.lease_count),
        alloc_count=zero(fleet.alloc_count),
        length=fleet.length.at[rows].set(1),
        overflow=fleet.overflow.at[rows].set(False),
        snap_dropped=fleet.snap_dropped.at[rows].set(False),
        cold_count=fleet.cold_count.at[rows].set(0),
    )


def attach_tenant(fleet: ChainFleet, t: int, *,
                  scalable: bool | None = None,
                  registry=None) -> ChainFleet:
    """(Re)initialize tenant slot ``t`` for a new occupant: a fresh empty
    length-1 chain with the given format flag (default: keep the slot's
    current flag). Any leases the slot still held are released first
    (``free_tenant``, honouring ``registry`` pins), so reused slots can
    never leak a predecessor's rows or tables."""
    out = free_tenant(fleet, t, registry=registry)
    if scalable is None:
        return out
    return dataclasses.replace(
        out, scalable=out.scalable.at[t].set(bool(scalable))
    )


@partial(jax.jit, static_argnames=("bump",))
def _clone_rows(l1, l2, length, scalable, src, dst, *, bump: bool = False):
    # src/dst arrive TRACED so every fork of a fresh tenant slot reuses
    # one compiled scatter — python-int indexing would bake each new
    # tenant id into the HLO and recompile per fork (serving admission
    # forks at request rate; a compile per fork dwarfs the fork itself)
    new_len = length[src] + (1 if bump else 0)
    return (l1.at[dst].set(l1[src]),
            l2.at[dst].set(l2[src]),
            length.at[dst].set(new_len),
            scalable.at[dst].set(scalable[src]))


def _clone_into(fleet: ChainFleet, src: int, dst: int, *,
                bump: bool) -> ChainFleet:
    if int(fleet.cold_count[src]) > 0:
        raise ValueError(
            f"tenant {src} holds host-tier rows; promote_tenants before "
            "cloning (cold entries cannot be shared across tenants)"
        )
    l1, l2, length, scalable = _clone_rows(
        fleet.l1, fleet.l2, fleet.length, fleet.scalable,
        jnp.int32(src), jnp.int32(dst), bump=bump)
    return dataclasses.replace(fleet, l1=l1, l2=l2, length=length,
                               scalable=scalable)


def clone_tenant(fleet: ChainFleet, src: int, dst: int) -> ChainFleet:
    """Copy tenant ``src``'s chain metadata (L1/L2 stacks, length, format
    flag) into slot ``dst``. Pool rows are shared, not copied: the
    clone's entries keep referencing the source's rows, so the *caller*
    owns cross-tenant row lifetime (the serving plane refcounts KV blocks
    host-side). Do NOT run the lease-accounted maintenance ops
    (``stream_tenants``/``compact``) on a fleet holding clones — their
    repack assumes per-tenant row disjointness and would flag the shared
    rows as corruption. Raises if ``src`` holds demoted (host-tier) rows:
    a cloned COLD entry would alias the host row across tenants and
    freeing either tenant would dangle the other — promote first
    (``promote_tenants``)."""
    return _clone_into(fleet, src, dst, bump=False)


def fork_tenant(fleet: ChainFleet, src: int, dst: int) -> ChainFleet:
    """Serving-plane fork: clone ``src``'s chain into ``dst`` and open a
    fresh (all-zeros) active volume on top — the vanilla "snapshot into a
    new tenant". ``dst`` resolves exactly like ``src`` until it writes;
    ``src`` keeps writing its own active volume independently. Raises if
    ``src`` is already at ``max_chain`` (callers grow the fleet geometry
    first — see ``PagedKVCache._grow_fleet``)."""
    if int(fleet.length[src]) >= fleet.spec.max_chain:
        raise ValueError(
            f"tenant {src} is at max_chain={fleet.spec.max_chain}; "
            "grow the fleet geometry before forking"
        )
    return _clone_into(fleet, src, dst, bump=True)


def stamp_entries(fleet: ChainFleet, tenants, layers, pages,
                  entries) -> ChainFleet:
    """Raw batched L2/L1 stamp at explicit ``(tenant, layer, page)`` sites.

    The serving plane's COW-prepare write: pool rows are allocated by the
    caller (the KV cache's refcounted block pool), so unlike ``write`` no
    lease is acquired and the fleet pool is untouched — this stamps index
    metadata only, one scatter for the whole batch. ``entries``: (K, 2)
    uint32 packed via ``fmt.pack_entry``. A tenant id of ``n_tenants``
    (out-of-bounds HIGH) acts as a drop sentinel, so callers can pad the
    batch to a fixed K without re-tracing; negative ids are invalid (they
    would wrap in the scatter)."""
    spec = fleet.spec
    t = jnp.asarray(tenants, jnp.int32)
    lay = jnp.asarray(layers, jnp.int32)
    p = jnp.asarray(pages, jnp.int32)
    ent = jnp.asarray(entries, jnp.uint32)
    l2 = fleet.l2.at[t, lay, p].set(ent, mode="drop")
    l1 = fleet.l1.at[t, lay, p // spec.l2_per_table].set(
        jnp.uint32(1), mode="drop"
    )
    return dataclasses.replace(fleet, l1=l1, l2=l2)


# -- migration support: explicit row grants and whole-slot installs ----------
#
# ``core.migrate`` packs a tenant into a portable blob and re-attaches it
# on another fleet. The lease-accounted halves of that live here, in the
# state's owner module: granting device rows to a tenant outside the
# ``write`` path, and installing a complete chain (stacks + pool pages)
# into a slot in one shot.


def acquire_rows(fleet: ChainFleet, t: int, n: int):
    """Grant tenant ``t`` ownership of ``n`` fresh device pool rows.

    The lease-accounted allocation primitive for callers that place page
    data themselves (migration's attach path): quanta are acquired on
    demand exactly as in ``write``, and ``alloc_count`` grows by ``n`` so
    the granted rows are the tenant's next ``n`` lease-order slots.

    Args:
        fleet: the fleet state (returned updated, never mutated).
        t: the receiving tenant.
        n: device rows to grant.

    Returns:
        ``(fleet, rows)`` — ``rows`` is an (n,) int64 numpy array of
        global pool row ids, in lease order. Raises ``RuntimeError`` if
        the pool cannot serve the grant (no partial grants: the lease
        state is returned untouched in that case because the update is
        functional).
    """
    spec = fleet.spec
    if n <= 0:
        return fleet, np.zeros(0, np.int64)
    need = np.zeros(spec.n_tenants, np.int32)
    need[t] = n
    lease_owner, lease_index, lease_count, short = _acquire_leases(
        fleet, jnp.asarray(need)
    )
    if bool(np.asarray(short)[t]):
        raise RuntimeError(
            f"pool exhausted granting {n} rows to tenant {t}: free or "
            "stream other tenants first"
        )
    rows, leased = _rows_for(spec, lease_index, fleet.alloc_count, n)
    rows_t = np.asarray(rows)[t].astype(np.int64)
    if not np.asarray(leased)[t].all():
        raise RuntimeError(
            f"lease table cannot address {n} more rows for tenant {t}"
        )
    out = dataclasses.replace(
        fleet,
        lease_owner=lease_owner,
        lease_index=lease_index,
        lease_count=lease_count,
        alloc_count=fleet.alloc_count + jnp.asarray(need),
    )
    return out, rows_t


def install_tenant(fleet: ChainFleet, t: int, *, l1, l2, length: int,
                   scalable: bool, cold_count: int = 0,
                   pool_rows=None, pool_data=None) -> ChainFleet:
    """Install a complete chain into tenant slot ``t`` in one shot.

    The attach half of migration: the slot's L1/L2 stacks are replaced
    wholesale (layers past ``length`` zeroed), its ``length``/format/
    ``cold_count`` set, and — when given — ``pool_data`` scattered into
    ``pool_rows`` (rows the caller obtained from ``acquire_rows``; this
    is the blob's page payload landing in the device pool). The pressure
    flags reset: an imported chain starts clean.

    The caller is responsible for slot hygiene (run ``free_tenant``
    first so a predecessor's leases are returned) and for the entries in
    ``l2`` pointing only at rows granted to ``t`` — ``core.migrate``
    remaps blob-local pointers before calling in, and the shared
    invariant suite (``core.invariants``) checks the result.
    """
    spec = fleet.spec
    length = int(length)
    if not 1 <= length <= spec.max_chain:
        raise ValueError(
            f"cannot install a length-{length} chain into a fleet with "
            f"max_chain={spec.max_chain}"
        )
    l1_full = np.zeros((spec.max_chain, spec.n_l1), np.uint32)
    l2_full = np.zeros((spec.max_chain, spec.n_pages, 2), np.uint32)
    l1_full[:length] = np.asarray(l1, np.uint32)
    l2_full[:length] = np.asarray(l2, np.uint32)
    pool = fleet.pool
    if pool_rows is not None and len(pool_rows):
        pool = pool.at[jnp.asarray(pool_rows, jnp.int32)].set(
            jnp.asarray(pool_data, spec.dtype)
        )
    return dataclasses.replace(
        fleet,
        l1=fleet.l1.at[t].set(jnp.asarray(l1_full)),
        l2=fleet.l2.at[t].set(jnp.asarray(l2_full)),
        pool=pool,
        length=fleet.length.at[t].set(length),
        scalable=fleet.scalable.at[t].set(bool(scalable)),
        overflow=fleet.overflow.at[t].set(False),
        snap_dropped=fleet.snap_dropped.at[t].set(False),
        cold_count=fleet.cold_count.at[t].set(int(cold_count)),
    )


# -- maintenance plane: streaming, GC, lease reclamation ---------------------


def _reclaim(fleet: ChainFleet, sel: np.ndarray, *,
             shared_rows=None) -> ChainFleet:
    """Repack each selected tenant's live rows into its leading lease
    quanta and return now-empty quanta to the allocator free list.

    Host-side (like ``chain.compact_pool``). Per selected tenant: gather
    the pool rows its live L2 entries reference, copy them — the streaming
    job's data movement — into the densest prefix of its leased quanta,
    remap the L2 pointers, then release every quantum past the packed
    prefix (``lease_owner`` → -1, ``lease_index``/``lease_count``/
    ``alloc_count`` shrink). ``overflow`` clears only for tenants whose
    row count actually shrank — reclaiming zero rows leaves the tenant as
    wedged as before, and clearing the flag would hide that.

    ``shared_rows`` (the golden registry's ``pinned_rows()``) marks rows
    a tenant may legally reference *without owning*: a golden fork's
    entries alias its base's frozen rows. Like COLD entries, shared rows
    are not repacked, keep their pointer verbatim, and never count
    toward the referencing tenant's lease footprint — the owner tenant
    (excluded from maintenance while registered) keeps them pinned.
    """
    spec = fleet.spec
    q = spec.lease_quantum
    lease_owner = np.asarray(fleet.lease_owner).copy()
    lease_index = np.asarray(fleet.lease_index).copy()
    lease_count = np.asarray(fleet.lease_count).copy()
    alloc_count = np.asarray(fleet.alloc_count).copy()
    lengths = np.asarray(fleet.length)
    reclaimed = np.zeros(spec.n_tenants, np.int64)
    pool = fleet.pool
    l2 = fleet.l2
    shared_lut = None
    if shared_rows is not None and len(shared_rows):
        shared_lut = np.zeros(spec.pool_capacity, bool)
        shared_lut[np.asarray(shared_rows, np.int64)] = True

    for t in np.flatnonzero(sel):
        length_t = int(lengths[t])
        entries = l2[t, :length_t]                    # (L, n_pages, 2)
        alloc = np.asarray(fmt.entry_allocated(entries))
        cold = np.asarray(fmt.entry_cold(entries))
        # ZERO clusters are allocated but their ptr is never dereferenced —
        # they pin no pool row; COLD entries point at the host tier, so
        # they pin no *device* row either (and their ptr must not be
        # remapped by the repack LUT below)
        live = alloc & ~np.asarray(fmt.entry_zero(entries)) & ~cold
        rows = np.asarray(fmt.entry_ptr(entries))
        sharedm = np.zeros(live.shape, bool)
        if shared_lut is not None:
            sharedm = live & shared_lut[np.where(live, rows, 0)]
            live = live & ~sharedm
        used = np.unique(rows[live]).astype(np.int64)  # sorted global rows
        n_live = len(used)
        if n_live and not np.all(lease_owner[used // q] == t):
            raise RuntimeError(
                f"tenant {t} references pool rows outside its leased "
                "quanta: fleet state is corrupt"
            )
        n_keep = -(-n_live // q)
        if n_live:
            keep = lease_index[t, :n_keep]
            i = np.arange(n_live)
            new_rows = keep[i // q] * q + i % q
            # gather-then-scatter: values materialize before the write, so
            # overlapping old/new rows inside the kept quanta are safe
            vals = pool[jnp.asarray(used, jnp.int32)]
            pool = pool.at[jnp.asarray(new_rows, jnp.int32)].set(vals)
            lut = np.zeros(spec.pool_capacity, np.uint32)
            lut[used] = new_rows.astype(np.uint32)
            # COLD entries keep their (host-tier) ptr verbatim, and so do
            # shared golden rows (another tenant's pinned, un-repacked
            # rows): the LUT maps this tenant's own device rows only
            safe = np.where(live, rows, 0)
            new_ptr = np.where(cold | sharedm, rows, lut[safe])
            new_entries = fmt.pack_entry(
                jnp.asarray(new_ptr.astype(np.uint32)),
                fmt.entry_bfi(entries),
                allocated=jnp.asarray(alloc),
                bfi_valid=fmt.entry_bfi_valid(entries),
                zero=fmt.entry_zero(entries),
                cold=jnp.asarray(cold),
            )
            l2 = l2.at[t, :length_t].set(new_entries)
        freed = lease_index[t, n_keep:lease_count[t]]
        lease_owner[freed] = -1
        lease_index[t, n_keep:] = -1
        lease_count[t] = n_keep
        reclaimed[t] = int(alloc_count[t]) - n_live
        alloc_count[t] = n_live

    overflow = np.asarray(fleet.overflow) & ~(reclaimed > 0)
    return dataclasses.replace(
        fleet,
        l2=l2,
        pool=pool,
        lease_owner=jnp.asarray(lease_owner, jnp.int32),
        lease_index=jnp.asarray(lease_index, jnp.int32),
        lease_count=jnp.asarray(lease_count, jnp.int32),
        alloc_count=jnp.asarray(alloc_count, jnp.int32),
        overflow=jnp.asarray(overflow, bool),
    )


def stream_tenants(fleet: ChainFleet, mask, merge_upto, *,
                   reclaim: bool = True, registry=None) -> ChainFleet:
    """Stream (merge layers ``[0, merge_upto]``) each selected tenant and
    return the pool quanta this frees to the lease allocator.

    The fleet-granularity analogue of ``chain.stream``: host-side
    maintenance over the stacked (T, C, P) layout, built on the same
    ``chain.merge_tables`` core so chain and fleet semantics cannot drift.

    Args:
        fleet: the fleet state (returned updated, never mutated).
        mask: (T,) bool, or a scalar broadcast over tenants — which
            tenants to stream this call.
        merge_upto: int or (T,) int — per tenant, merge layers
            ``[0, merge_upto]`` into the base. Tenants whose
            ``merge_upto`` does not fall strictly below their active
            volume are skipped (a background job must tolerate racing
            chain growth, where ``chain.stream`` raises).
        reclaim: run the shared ``_reclaim`` repack afterwards (default).
            Pass ``False`` for a metadata-only merge that frees nothing.
        registry: the ``GoldenRegistry``, when the fleet runs one.
            Registered golden *owners* are skipped (their chains are
            content-frozen; a merge would invalidate every fork's base)
            and forks' shared base rows ride through the repack
            untouched (``_reclaim(shared_rows=...)``).

    Returns:
        The updated ``ChainFleet``. With ``reclaim=True``, rows orphaned
        by the merge leave each tenant's lease footprint and freed quanta
        return to the allocator free list; ``overflow`` clears only for
        tenants that actually shrank, and ``snap_dropped`` clears only
        where streaming made room below ``max_chain``.
    """
    spec = fleet.spec
    t = spec.n_tenants
    mask = np.broadcast_to(np.asarray(mask, bool), (t,))
    upto = np.broadcast_to(np.asarray(merge_upto, np.int64), (t,))
    lengths = np.asarray(fleet.length).copy()
    # tenants holding demoted pages are skipped: merging layers would
    # collapse COLD entries across layer boundaries and strand their host
    # rows — promote_tenants first, then stream
    cold = np.asarray(fleet.cold_count)
    sel = mask & (upto >= 0) & (upto < lengths - 1) & (cold == 0)
    if registry is not None:
        sel &= ~registry.golden_owner_mask(t)

    l1, l2 = fleet.l1, fleet.l2
    snap_dropped = np.asarray(fleet.snap_dropped).copy()
    scalable = np.asarray(fleet.scalable)
    sel_idx = np.flatnonzero(sel)
    if sel_idx.size:
        merged_l1, merged_l2 = [], []
        for i in sel_idx:
            tl1, tl2, new_len = chain_lib.merge_tables(
                l1[i], l2[i], int(lengths[i]), int(upto[i]),
                scalable=bool(scalable[i]),
            )
            merged_l1.append(tl1)
            merged_l2.append(tl2)
            lengths[i] = new_len
            snap_dropped[i] &= new_len >= spec.max_chain
        # one stacked scatter per array: updating tenant-by-tenant would
        # copy the full (T, C, ...) stacks once per selected tenant
        idx = jnp.asarray(sel_idx, jnp.int32)
        l1 = l1.at[idx].set(jnp.stack(merged_l1))
        l2 = l2.at[idx].set(jnp.stack(merged_l2))
    out = dataclasses.replace(
        fleet,
        l1=l1,
        l2=l2,
        length=jnp.asarray(lengths, jnp.int32),
        snap_dropped=jnp.asarray(snap_dropped, bool),
    )
    if not reclaim:
        return out
    return _reclaim(
        out, sel,
        shared_rows=registry.pinned_rows() if registry is not None else None,
    )


def compact(fleet: ChainFleet, mask=None, *, registry=None) -> ChainFleet:
    """Fleet-level GC: repack every (selected) tenant's live rows and
    return the freed quanta to the allocator free list.

    The fleet analogue of ``chain.compact_pool`` — COW writes and
    streaming orphan pool rows; this is the background job that hands
    them back so long-running fleets reach a steady state instead of
    leaking the pool.

    Args:
        fleet: the fleet state (returned updated, never mutated).
        mask: optional (T,) bool selecting which tenants to repack;
            ``None`` (default) compacts every tenant.
        registry: the ``GoldenRegistry``, when the fleet runs one.
            Golden owners are never repacked (their pointer layout is
            part of the frozen fingerprint and their rows are pinned);
            forks repack only their own rows, aliased base rows ride
            through verbatim.

    Returns:
        The updated ``ChainFleet``: selected tenants' live rows repacked
        into their leading lease quanta, emptied quanta returned to the
        free list, and ``overflow`` cleared only for tenants whose rows
        were actually reclaimed (reclaiming nothing leaves the tenant as
        wedged as before).
    """
    t = fleet.spec.n_tenants
    sel = (np.ones(t, bool) if mask is None
           else np.broadcast_to(np.asarray(mask, bool), (t,)))
    if registry is None:
        return _reclaim(fleet, sel)
    sel = sel & ~registry.golden_owner_mask(t)
    return _reclaim(fleet, sel, shared_rows=registry.pinned_rows())


# -- tiering: HBM <-> host demotion and promotion ----------------------------
#
# The second tier of the page pool (paper's 15x memory headline at
# fleet granularity): immutable snapshot layers spill to a host-side
# ``store.TieredStore`` under HBM pressure and come back on demand. A
# demoted entry keeps its layer position — only its ptr is rewritten to a
# host-tier row under FLAG_COLD, so resolution semantics (owner, found,
# zero, lookups) are untouched and the stacked resolvers simply report
# ``cold``. See docs/memory.md for the full lifecycle.


def _tenant_cold_rows(l2_t: np.ndarray, length_t: int):
    """Cold entries of one tenant: (layer, page) mask + their host rows.

    Pure numpy on an already-synced L2 copy — the tiering maintenance
    paths stay off the device except for the actual page transfers."""
    w0 = l2_t[:length_t, ..., 0]
    coldm = ((w0 & np.uint32(fmt.FLAG_COLD)) != 0) \
        & ((w0 & np.uint32(fmt.FLAG_ALLOCATED)) != 0) \
        & ((w0 & np.uint32(fmt.FLAG_ZERO)) == 0)
    return coldm, (w0 & np.uint32(fmt.PTR_MASK)).astype(np.int64)


def demote_tenants(fleet: ChainFleet, store, tenants, *,
                   max_rows: int | None = None,
                   verify: bool = True, registry=None):
    """Demote immutable snapshot-layer pages of the selected tenants to
    the host tier, freeing their device rows.

    Only pages **owned by a layer below the active volume** are eligible —
    the active COW layer's own data never moves (it is the hot, mutable
    set). A page's owner is the lowest layer referencing its row, which
    under snapshot copy-forward means every upper layer (including the
    active one) referencing that row has its entry rewritten to the host
    row under ``FLAG_COLD`` in the same transfer, so the index never
    dangles. The freed device rows then leave the tenant's lease
    footprint via the shared ``_reclaim`` repack and their quanta return
    to the allocator free list — this is where the HBM actually comes
    back.

    Host-side (maintenance plane). Transfers are batched per call and
    bit-verified by default: the host copy is read back and compared
    bitwise against the device rows before the index is rewritten.

    Args:
        fleet: the fleet state (returned updated, never mutated).
        store: the ``TieredStore`` cold tier receiving the pages.
        tenants: int id, id sequence, or (T,) bool mask.
        max_rows: demote at most this many pool rows across the call
            (the scheduler's per-tick budget); ``None`` = no cap.
            Oldest layers go first, so repeated budgeted calls demote
            coldest-first.
        verify: bit-verify every transferred row (default True).
        registry: the ``GoldenRegistry``, when the fleet runs one.
            Registered golden owners are skipped entirely (the frozen
            base stays device-resident by contract), and rows pinned by
            the registry are never picked from *any* tenant — a fork's
            lower layers reference the shared base below its active
            volume, exactly the demotion-eligible shape, and spilling
            them would pull the base out from under every sibling fork.

    Returns:
        ``(fleet, report)`` where report is
        ``dict(rows_demoted=int, tenants=[ids that moved rows])``.
    """
    spec = fleet.spec
    sel = _tenant_sel(spec.n_tenants, tenants)
    pinned_lut = None
    if registry is not None:
        sel &= ~registry.golden_owner_mask(spec.n_tenants)
        pinned = registry.pinned_rows()
        if pinned.size:
            pinned_lut = np.zeros(spec.pool_capacity, bool)
            pinned_lut[pinned] = True
    lengths = np.asarray(fleet.length)
    cold_count = np.asarray(fleet.cold_count).copy()
    # one full host copy, modified in place and pushed back once: entry
    # rewriting stays in numpy at fixed shapes (per-tenant device slices
    # of varying chain length would recompile every tick)
    l2_np = np.array(fleet.l2)
    budget = np.inf if max_rows is None else int(max_rows)
    total = 0
    moved: list[int] = []

    for t in np.flatnonzero(sel):
        if budget <= 0:
            break
        length_t = int(lengths[t])
        if length_t < 2:
            continue                       # nothing below the active volume
        entries = l2_np[t, :length_t]                # (L, n_pages, 2) view
        w0 = entries[..., 0]
        alloc = (w0 & np.uint32(fmt.FLAG_ALLOCATED)) != 0
        cold = (w0 & np.uint32(fmt.FLAG_COLD)) != 0
        hot = alloc & ((w0 & np.uint32(fmt.FLAG_ZERO)) == 0) & ~cold
        rows = (w0 & np.uint32(fmt.PTR_MASK)).astype(np.int64)
        if pinned_lut is not None:
            # golden-pinned rows are immovable while any fork aliases them
            hot &= ~pinned_lut[np.where(hot, rows, 0)]
        if not hot.any():
            continue
        # a row's owner is the lowest layer referencing it (copy-forward
        # re-references ancestor rows from every upper layer)
        layer_idx = np.broadcast_to(
            np.arange(length_t)[:, None], hot.shape)
        flat_rows = rows[hot]
        flat_layer = layer_idx[hot]
        order = np.argsort(flat_rows, kind="stable")
        r_sorted, l_sorted = flat_rows[order], flat_layer[order]
        first = np.r_[True, r_sorted[1:] != r_sorted[:-1]]
        uniq_rows = r_sorted[first]
        owner_layer = np.minimum.reduceat(l_sorted, np.flatnonzero(first))
        eligible = owner_layer < length_t - 1        # never the active layer
        uniq_rows, owner_layer = uniq_rows[eligible], owner_layer[eligible]
        if uniq_rows.size == 0:
            continue
        # coldest first: demote the oldest layers' rows under the budget
        pick = np.argsort(owner_layer, kind="stable")
        if uniq_rows.size > budget:
            pick = pick[: int(budget)]
        dem_rows = uniq_rows[pick]
        n = int(dem_rows.size)

        host_rows = store.alloc(n)
        vals = np.asarray(fleet.pool[jnp.asarray(dem_rows, jnp.int32)])
        store.put(host_rows, vals)
        if verify and not np.array_equal(
                store.get(host_rows).view(np.uint8),
                vals.view(np.uint8)):
            raise RuntimeError(
                f"demotion transfer verification failed for tenant {t}"
            )
        # rewrite every entry (any layer) referencing a demoted row:
        # ptr -> host row, FLAG_COLD set; all other bits carried
        lut = np.zeros(spec.pool_capacity, np.int64)
        in_set = np.zeros(spec.pool_capacity, bool)
        lut[dem_rows] = host_rows
        in_set[dem_rows] = True
        hit = hot & in_set[np.where(hot, rows, 0)]
        new_ptr = np.where(hit, lut[np.where(hit, rows, 0)], rows)
        entries[..., 0] = np.where(
            hit,
            (w0 & ~np.uint32(fmt.PTR_MASK))
            | new_ptr.astype(np.uint32)
            | np.uint32(fmt.FLAG_COLD),
            w0,
        )
        cold_count[t] += n
        budget -= n
        total += n
        moved.append(int(t))

    if not moved:
        return fleet, dict(rows_demoted=0, tenants=[])
    out = dataclasses.replace(
        fleet, l2=jnp.asarray(l2_np),
        cold_count=jnp.asarray(cold_count, jnp.int32)
    )
    # repack: the demoted rows are no longer referenced by any hot entry,
    # so _reclaim returns their quanta to the allocator free list
    out = _reclaim(
        out, _tenant_sel(spec.n_tenants, moved),
        shared_rows=registry.pinned_rows() if registry is not None else None,
    )
    return out, dict(rows_demoted=total, tenants=moved)


def promote_tenants(fleet: ChainFleet, store, tenants, *,
                    max_rows: int | None = None,
                    verify: bool = True):
    """Promote the selected tenants' demoted pages back into the device
    pool (the inverse of ``demote_tenants``).

    Fresh device rows come from the tenant's own lease allocator
    (acquiring quanta on demand); the host copies are scattered in, every
    COLD entry referencing them is rewritten to the new device row with
    the residency bit cleared, and the host rows return to the store's
    free list. Bit-verified by default: the device rows are read back and
    compared against the host copies. Raises if the pool cannot grant
    enough quanta — callers demote (or free) someone else first.

    Args:
        fleet: the fleet state (returned updated, never mutated).
        store: the ``TieredStore`` the pages were demoted into.
        tenants: int id, id sequence, or (T,) bool mask.
        max_rows: promote at most this many rows across the call
            (``None`` = everything cold the selected tenants hold).
        verify: bit-verify every transferred row (default True).

    Returns:
        ``(fleet, report)``: ``dict(rows_promoted=int, tenants=[...])``.
    """
    spec = fleet.spec
    sel = _tenant_sel(spec.n_tenants, tenants)
    lengths = np.asarray(fleet.length)
    cold_count = np.asarray(fleet.cold_count)
    # one full host copy (see demote_tenants): entry rewriting stays in
    # numpy at fixed shapes and ships back to the device in one transfer
    l2_np = np.array(fleet.l2)
    budget = np.inf if max_rows is None else int(max_rows)

    # pick the host rows to promote per tenant, under the budget
    plans: dict[int, np.ndarray] = {}        # t -> host rows
    masks: dict[int, np.ndarray] = {}        # t -> cold entry mask
    rows_all: dict[int, np.ndarray] = {}     # t -> ptr field per entry
    need = np.zeros(spec.n_tenants, np.int32)
    for t in np.flatnonzero(sel & (cold_count > 0)):
        if budget <= 0:
            break
        coldm, rows = _tenant_cold_rows(l2_np[t], int(lengths[t]))
        host_rows = np.unique(rows[coldm])
        if host_rows.size > budget:
            host_rows = host_rows[: int(budget)]
        if host_rows.size == 0:
            continue
        plans[int(t)] = host_rows
        masks[int(t)] = coldm
        rows_all[int(t)] = rows
        need[t] = host_rows.size
        budget -= host_rows.size
    if not plans:
        return fleet, dict(rows_promoted=0, tenants=[])

    lease_owner, lease_index, lease_count, short = _acquire_leases(
        fleet, jnp.asarray(need)
    )
    short_np = np.asarray(short)
    if np.any(short_np[list(plans)]):
        bad = [t for t in plans if short_np[t]]
        raise RuntimeError(
            f"device pool exhausted promoting tenants {bad}: demote or "
            "free other tenants first"
        )
    bsz = int(np.max(need))
    dev_rows, _ = _rows_for(spec, lease_index, fleet.alloc_count, bsz)
    dev_rows = np.asarray(dev_rows)

    # one batched scatter for the whole call's data movement
    all_dev, all_host = [], []
    for t, host_rows in plans.items():
        all_dev.append(dev_rows[t, : host_rows.size])
        all_host.append(host_rows)
    dev_cat = np.concatenate(all_dev)
    host_cat = np.concatenate(all_host)
    vals = store.get(host_cat)
    pool = fleet.pool.at[jnp.asarray(dev_cat, jnp.int32)].set(
        jnp.asarray(vals)
    )
    if verify and not np.array_equal(
            np.asarray(pool[jnp.asarray(dev_cat, jnp.int32)]).view(np.uint8),
            vals.view(np.uint8)):
        raise RuntimeError("promotion transfer verification failed")

    # rewrite the promoted COLD entries: host row -> device row, bit clear
    alloc_count = np.asarray(fleet.alloc_count).copy()
    new_cold = np.asarray(fleet.cold_count).copy()
    for t, host_rows in plans.items():
        length_t = int(lengths[t])
        w0 = l2_np[t, :length_t, ..., 0]             # in-place view
        coldm, rows = masks[t], rows_all[t]
        promoting = coldm & np.isin(rows, host_rows)
        # host_rows is np.unique output (sorted) — searchsorted maps each
        # promoted entry's host row to its fresh device row
        idx = np.searchsorted(host_rows, rows[promoting])
        new_ptr = dev_rows[t, : host_rows.size][idx].astype(np.uint32)
        w0[promoting] = (
            (w0[promoting]
             & ~np.uint32(fmt.PTR_MASK) & ~np.uint32(fmt.FLAG_COLD))
            | new_ptr
        )
        alloc_count[t] += host_rows.size
        new_cold[t] -= host_rows.size
        store.free(host_rows)
        store.promoted_rows += int(host_rows.size)

    out = dataclasses.replace(
        fleet,
        l2=jnp.asarray(l2_np),
        pool=pool,
        lease_owner=lease_owner,
        lease_index=lease_index,
        lease_count=lease_count,
        alloc_count=jnp.asarray(alloc_count, jnp.int32),
        cold_count=jnp.asarray(new_cold, jnp.int32),
    )
    return out, dict(rows_promoted=int(sum(need)), tenants=sorted(plans))


def read_tiered(fleet: ChainFleet, store, page_ids, *,
                method: str = "auto"):
    """Batched fleet read that serves cold pages from the host tier.

    The device gather (``read``) masks cold hits to zeros; this host-side
    wrapper fills exactly those positions from the ``TieredStore``. The
    serving path never calls this — it promotes before reading — but the
    maintenance/verification plane (and the tiering benchmark's
    bit-verify pass) read through it without perturbing residency.

    Returns ``(data (T, B, page_size) numpy, ResolveResult)``.
    """
    data, res = read(fleet, jnp.asarray(page_ids, jnp.int32), method=method)
    data = np.array(data)        # writable host copy (asarray is read-only)
    coldm = np.asarray(res.cold & res.found & ~res.zero)
    if coldm.any():
        host_rows = np.asarray(res.ptr)[coldm].astype(np.int64)
        data[coldm] = store.get(host_rows)
    return data, res


# -- per-tenant views & host-side helpers ------------------------------------


def tenant_chain(fleet: ChainFleet, t: int) -> Chain:
    """A read-only single-``Chain`` view of tenant ``t``.

    Shares the fleet's global pool, so resolvers and reads on the view
    agree bit-for-bit with the batched fleet paths. Do **not** run any
    mutating single-chain op (``write``, ``stream``, ``compact_pool``,
    ``convert_to_scalable``) through the view: they allocate from a linear
    cursor, not the fleet allocator's leases, and would corrupt other
    tenants. The view's ``pool_cursor`` is pinned to ``pool_capacity`` so
    an accidental ``write`` flags overflow immediately and ``stream``
    raises rather than scribbling over foreign leases.
    """
    return Chain(
        spec=fleet.spec.chain_spec(),
        scalable=bool(fleet.scalable[t]),
        l1=fleet.l1[t],
        l2=fleet.l2[t],
        pool=fleet.pool,
        pool_cursor=jnp.asarray(fleet.spec.pool_capacity, jnp.int32),
        length=fleet.length[t],
        overflow=fleet.overflow[t],
        snap_dropped=fleet.snap_dropped[t],
    )


def check_pool_capacity(fleet: ChainFleet) -> None:
    """Raise if any tenant hit a resource limit (host-side guard)."""
    bad = np.flatnonzero(np.asarray(fleet.overflow))
    if bad.size:
        raise RuntimeError(
            f"page pool exhausted for tenants {bad.tolist()}: grow "
            "FleetSpec.pool_capacity or stream/compact their chains"
        )
    capped = np.flatnonzero(np.asarray(fleet.snap_dropped))
    if capped.size:
        raise RuntimeError(
            f"snapshot dropped for tenants {capped.tolist()}: their chains "
            "are at max_chain; stream them to make room"
        )


def fleet_stats(fleet: ChainFleet) -> dict:
    """Host-side occupancy summary (monitoring / benchmark reporting)."""
    owner = np.asarray(fleet.lease_owner)
    return dict(
        n_tenants=fleet.spec.n_tenants,
        quanta_total=fleet.spec.n_quanta,
        quanta_leased=int(np.sum(owner >= 0)),
        quanta_free=int(np.sum(owner < 0)),
        rows_allocated=int(np.sum(np.asarray(fleet.alloc_count))),
        mean_chain_length=float(np.mean(np.asarray(fleet.length))),
        overflowed_tenants=int(np.sum(np.asarray(fleet.overflow))),
        snapshot_capped_tenants=int(np.sum(np.asarray(fleet.snap_dropped))),
        rows_cold=int(np.sum(np.asarray(fleet.cold_count))),
        cold_tenants=int(np.sum(np.asarray(fleet.cold_count) > 0)),
    )


def tenant_stats(fleet: ChainFleet) -> dict:
    """Per-tenant occupancy arrays — the scheduler's ranking signal.

    The per-tenant counterpart of ``fleet_stats``: (T,) numpy arrays of
    chain ``length``, ``alloc_count`` (pool rows held), ``lease_count``
    (quanta held) and the ``overflow``/``snap_dropped`` pressure flags.
    """
    return dict(
        length=np.asarray(fleet.length),
        alloc_count=np.asarray(fleet.alloc_count),
        lease_count=np.asarray(fleet.lease_count),
        overflow=np.asarray(fleet.overflow),
        snap_dropped=np.asarray(fleet.snap_dropped),
        cold_count=np.asarray(fleet.cold_count),
    )
