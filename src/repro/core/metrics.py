"""Analytical cost models from the paper (Eq. 1, Eq. 2) + latency mapping.

Eq. 1 — average lookup cost on a chain of length N::

    Y = [(Hit% * T_M) + (Miss% * (T_D + T_L + T_F)) + (UnAl% * T_F)] * N

with T_M the RAM access time (~100 ns), T_D the disk access time (~80 us),
T_L the software/network traversal time (~1 us) and T_F the per-event
driver overhead (~1 us; unnamed constant in the paper). On TPU the same
structure holds with T_M ≈ VMEM hit, T_D ≈ HBM page fetch, T_L ≈ kernel
dispatch; the *shape* (linear in N for vanilla, N-independent for direct)
is the claim being reproduced, so the constants are parameters.

Eq. 2 — per-snapshot metadata overhead of the scalable format::

    S_sq = S_vq + disk_size / cluster_size * l2_entry_size

Tiering (paper §6.3's 15x memory headline, fleet-granularity analogue):
``tier_residency`` snapshots the two-tier pool occupancy off a fleet +
``TieredStore`` pair — the counters benchmarks and tests assert on
instead of peeking at allocator internals — and ``tiered_pool_bytes``
is the analytical bytes-resident-per-tenant model behind the cost table
in ``docs/memory.md``.

Golden-prefix dedup: ``golden_residency`` snapshots the shared-base
counters off a ``GoldenRegistry`` — the fleet-plane mirror of
``tier_residency``, asserted on by ``benchmarks/prefix.py``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt
from repro.core.cache import SimTrace
from repro.core.chain import ChainSpec


@dataclasses.dataclass(frozen=True)
class CostConstants:
    """Timing constants (seconds). Defaults are the paper's host values."""

    t_m: float = 100e-9   # cache/RAM probe
    t_d: float = 80e-6    # backing-store (disk/HBM) access
    t_l: float = 1e-6     # software + network layers
    t_f: float = 1e-6     # per hit-unallocated driver overhead


def eq1_average_cost(
    hit_pct: float,
    miss_pct: float,
    unal_pct: float,
    chain_length: int,
    c: CostConstants = CostConstants(),
) -> float:
    """Paper Eq. 1, verbatim."""
    return (
        hit_pct * c.t_m
        + miss_pct * (c.t_d + c.t_l + c.t_f)
        + unal_pct * c.t_f
    ) * chain_length


def eq2_snapshot_overhead_bytes(
    disk_size_bytes: int,
    cluster_size_bytes: int = 64 * 1024,
    l2_entry_size: int = 8,
    s_vq_bytes: int = 256 * 1024,
) -> int:
    """Paper Eq. 2: size of a fresh scalable snapshot file."""
    return s_vq_bytes + (disk_size_bytes // cluster_size_bytes) * l2_entry_size


def trace_latencies(trace: SimTrace, c: CostConstants = CostConstants()):
    """Per-request modelled lookup latency (seconds) from simulated events.

    Every probe costs a T_M, every slice fetch a T_D + T_L, every
    hit-unallocated a T_F — the event-level form of Eq. 1 (which is its
    expectation over a request stream).
    """
    return (
        trace.probes.astype(jnp.float64 if False else jnp.float32) * c.t_m
        + trace.misses.astype(jnp.float32) * (c.t_d + c.t_l)
        + trace.hit_unallocated.astype(jnp.float32) * c.t_f
    )


@dataclasses.dataclass(frozen=True)
class TierResidency:
    """One observation of the two-tier pool occupancy (see module doc)."""

    device_rows: int      # pool rows currently leased to tenants (HBM)
    host_rows: int        # rows resident in the TieredStore cold tier
    cold_tenants: int     # tenants holding at least one demoted row
    demoted_rows: int     # lifetime device -> host transfers (pages)
    promoted_rows: int    # lifetime host -> device transfers (pages)


def tier_residency(fleet, store=None) -> TierResidency:
    """Tier-residency counters from a fleet (+ optional ``TieredStore``).

    The supported observability surface for tiering: benchmarks and
    tests assert on these instead of reading allocator internals. With
    ``store=None`` the host-side counters read as zero (an untiered
    fleet is just an all-device pool).
    """
    cold = np.asarray(fleet.cold_count)
    return TierResidency(
        device_rows=int(np.sum(np.asarray(fleet.alloc_count))),
        host_rows=0 if store is None else store.host_rows_in_use(),
        cold_tenants=int(np.sum(cold > 0)),
        demoted_rows=0 if store is None else store.demoted_rows,
        promoted_rows=0 if store is None else store.promoted_rows,
    )


@dataclasses.dataclass(frozen=True)
class GoldenResidency:
    """One observation of the golden-prefix dedup state (core plane)."""

    golden_chains: int      # registered content-addressed bases
    golden_forks: int       # live tenants forked off a base
    golden_rows_pinned: int # distinct device rows pinned by bases
    dedup_rows_saved: int   # rows a dedup-free fleet would also hold


def golden_residency(registry) -> GoldenResidency:
    """Golden-registry counters off a ``core.golden.GoldenRegistry``.

    The supported observability surface for prefix dedup on the fleet
    plane — the mirror of ``tier_residency`` for the golden registry.
    ``dedup_rows_saved`` sums, over every live fork, the shared rows the
    fork aliases instead of copying: the device rows a registry-free
    fleet would additionally lease to back the same tenants.
    """
    st = registry.stats()
    return GoldenResidency(
        golden_chains=st["golden_chains"],
        golden_forks=st["golden_forks"],
        golden_rows_pinned=st["golden_rows_pinned"],
        dedup_rows_saved=st["dedup_rows_saved"],
    )


def tiered_pool_bytes(spec: ChainSpec, chain_length: int,
                      rows_per_layer: int, *, tiered: bool) -> int:
    """Data-pool bytes resident in HBM for one tenant at depth D.

    Each snapshot layer freezes ``rows_per_layer`` pool rows (the pages
    it wrote). All-HBM, every layer's rows stay device-resident:
    ``D * rows_per_layer`` pages. Tiered, the steady state keeps only
    the active layer's rows hot — the demotion policy spills every
    immutable layer — so residency is ``rows_per_layer`` pages,
    independent of D. The ratio is the paper's deep-chain memory win
    (§6.3); ``benchmarks/tiering.py`` measures the realized ratio, this
    is the model it is checked against. Index metadata is not included
    (see ``index_bytes`` — it is identical in both configurations).
    """
    itemsize = jnp.zeros((), spec.dtype).dtype.itemsize
    rows = rows_per_layer * (1 if tiered else chain_length)
    return rows * spec.page_size * itemsize


def index_bytes(spec: ChainSpec, chain_length: int, *, scalable: bool) -> int:
    """On-disk index metadata bytes for a whole chain (Fig 19a analogue).

    Vanilla snapshots carry only L1 (+ lazily allocated L2 tables — we
    count the worst case, as the paper's model does); scalable snapshots
    always carry the full copied-forward L2 set.
    """
    l1 = spec.n_l1 * 4
    l2_full = spec.n_pages * fmt.ENTRY_WORDS * 4
    per_snapshot = l1 + l2_full if scalable else l1
    return chain_length * per_snapshot
