"""Analytical cost models from the paper (Eq. 1, Eq. 2) + latency mapping.

Eq. 1 — average lookup cost on a chain of length N::

    Y = [(Hit% * T_M) + (Miss% * (T_D + T_L + T_F)) + (UnAl% * T_F)] * N

with T_M the RAM access time (~100 ns), T_D the disk access time (~80 us),
T_L the software/network traversal time (~1 us) and T_F the per-event
driver overhead (~1 us; unnamed constant in the paper). On TPU the same
structure holds with T_M ≈ VMEM hit, T_D ≈ HBM page fetch, T_L ≈ kernel
dispatch; the *shape* (linear in N for vanilla, N-independent for direct)
is the claim being reproduced, so the constants are parameters.

Eq. 2 — per-snapshot metadata overhead of the scalable format::

    S_sq = S_vq + disk_size / cluster_size * l2_entry_size
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import format as fmt
from repro.core.cache import SimTrace
from repro.core.chain import ChainSpec


@dataclasses.dataclass(frozen=True)
class CostConstants:
    """Timing constants (seconds). Defaults are the paper's host values."""

    t_m: float = 100e-9   # cache/RAM probe
    t_d: float = 80e-6    # backing-store (disk/HBM) access
    t_l: float = 1e-6     # software + network layers
    t_f: float = 1e-6     # per hit-unallocated driver overhead


def eq1_average_cost(
    hit_pct: float,
    miss_pct: float,
    unal_pct: float,
    chain_length: int,
    c: CostConstants = CostConstants(),
) -> float:
    """Paper Eq. 1, verbatim."""
    return (
        hit_pct * c.t_m
        + miss_pct * (c.t_d + c.t_l + c.t_f)
        + unal_pct * c.t_f
    ) * chain_length


def eq2_snapshot_overhead_bytes(
    disk_size_bytes: int,
    cluster_size_bytes: int = 64 * 1024,
    l2_entry_size: int = 8,
    s_vq_bytes: int = 256 * 1024,
) -> int:
    """Paper Eq. 2: size of a fresh scalable snapshot file."""
    return s_vq_bytes + (disk_size_bytes // cluster_size_bytes) * l2_entry_size


def trace_latencies(trace: SimTrace, c: CostConstants = CostConstants()):
    """Per-request modelled lookup latency (seconds) from simulated events.

    Every probe costs a T_M, every slice fetch a T_D + T_L, every
    hit-unallocated a T_F — the event-level form of Eq. 1 (which is its
    expectation over a request stream).
    """
    return (
        trace.probes.astype(jnp.float64 if False else jnp.float32) * c.t_m
        + trace.misses.astype(jnp.float32) * (c.t_d + c.t_l)
        + trace.hit_unallocated.astype(jnp.float32) * c.t_f
    )


def index_bytes(spec: ChainSpec, chain_length: int, *, scalable: bool) -> int:
    """On-disk index metadata bytes for a whole chain (Fig 19a analogue).

    Vanilla snapshots carry only L1 (+ lazily allocated L2 tables — we
    count the worst case, as the paper's model does); scalable snapshots
    always carry the full copied-forward L2 set.
    """
    l1 = spec.n_l1 * 4
    l2_full = spec.n_pages * fmt.ENTRY_WORDS * 4
    per_snapshot = l1 + l2_full if scalable else l1
    return chain_length * per_snapshot
