"""Content-addressed golden-chain registry: shared-prefix fork dedup.

The fleet characterization in the paper (and the Aquifer bet in
PAPERS.md) is that most snapshot chains descend from a handful of golden
base images — thousands of disks sharing one read-only ancestor through
the overlay/backing-file idiom. This module makes that sharing a
first-class, *accounted* state of the fleet instead of an accident the
maintenance plane would flag as corruption:

* ``GoldenRegistry.register`` freezes a tenant's chain under a content
  hash built from the same localized ``TenantBlob`` packing migration
  uses (``core.migrate.export_tenant``), so two tenants holding
  bit-identical chains hash to the same golden id no matter how their
  pool rows are laid out. Registration is pure bookkeeping — no copy.
* ``GoldenRegistry.fork`` clones the frozen chain into a destination
  slot (``clone_tenant``) and opens a fresh active volume on top,
  optionally truncated to a shallower layer ``depth``. The fork's lower
  layers alias the owner's pool rows *by design*; per-layer refcounts
  record exactly which layers each live fork pins.
* The maintenance plane honours the pins: ``free_tenant`` refuses to
  drop a registered owner (and auto-releases forks), ``stream_tenants``
  / ``compact`` / ``demote_tenants`` skip owners and treat pinned rows
  as immovable (``_reclaim(shared_rows=...)``), so a shared base page
  is never repacked, reclaimed or spilled out from under a live fork.
* ``core.invariants.check_fleet_invariants`` takes the registry and
  turns the "no cross-tenant row aliasing" rule into "aliasing is legal
  exactly on a fork's pinned golden rows" — tracked, not forbidden.

The owner's chain must stay bit-frozen while registered: writes,
snapshots and maintenance repacks all change its migration fingerprint,
and ``GoldenRegistry.check``/``fork`` fail loudly on a mismatch (the
same staleness guard ``detach_tenant`` uses).

``PrefixTrie`` is the serving-plane half: a radix-style (path-
compressed) lookup keyed on token ids, mapping prompt prefixes to
registered golden sequences so ``Engine.add_request`` can fork the
deepest match and prefill only the suffix (see ``serve/engine.py`` and
``docs/architecture.md``).
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import chain as chain_lib
from repro.core import fleet as fleet_lib
from repro.core import format as fmt
from repro.core import migrate


def _blob_layer_hashes(blob) -> tuple[str, ...]:
    """Cumulative per-layer content hashes of an exported chain.

    Layer ``i``'s digest covers layers ``[0, i]``: the localized L1/L2
    words plus the bytes of every hot page those layers reference, in
    blob-local (layout-free) order. Two chains agree on ``hashes[i]``
    iff their first ``i + 1`` layers are guest-visibly identical, so
    the last entry is the chain's content address and any prefix of it
    addresses a shallower golden depth.
    """
    entries = blob.l2
    allocm = np.asarray(fmt.entry_allocated(entries))
    zerom = np.asarray(fmt.entry_zero(entries))
    hotm = allocm & ~zerom & ~np.asarray(fmt.entry_cold(entries))
    ptrs = np.asarray(fmt.entry_ptr(entries)).astype(np.int64)
    h = hashlib.sha256()
    out = []
    for i in range(blob.length):
        h.update(np.asarray(blob.l1[i]).tobytes())
        h.update(np.asarray(blob.l2[i]).tobytes())
        h.update(blob.hot_pages[np.unique(ptrs[i][hotm[i]])].tobytes())
        out.append(h.hexdigest())
    return tuple(out)


@dataclasses.dataclass
class GoldenChain:
    """One registered golden base: a frozen tenant chain plus the pins
    live forks hold on it. ``layer_refs[i]`` counts forks whose depth
    covers layer ``i`` (a depth-``d`` fork pins layers ``[0, d)``), so
    ``layer_refs[0]`` is the total live-fork count."""

    gid: int
    tenant: int
    length: int
    layer_hashes: tuple[str, ...]   # cumulative content hash per layer
    cum_rows: tuple[np.ndarray, ...]  # device rows pinned up to each depth
    layer_refs: np.ndarray          # (length,) int64 live-fork pins
    fingerprint: str                # migrate.tenant_fingerprint at register

    @property
    def content_hash(self) -> str:
        return self.layer_hashes[-1]

    @property
    def rows(self) -> np.ndarray:
        """Every device row the frozen chain references (sorted)."""
        return self.cum_rows[-1]

    @property
    def fork_count(self) -> int:
        return int(self.layer_refs[0]) if self.length else 0


class GoldenRegistry:
    """Fleet-side registry of golden chains and the forks pinning them.

    Host-side bookkeeping only — the registry never owns fleet state; it
    is threaded through the lifecycle/maintenance ops (``free_tenant``,
    ``stream_tenants``, ``compact``, ``demote_tenants``, the scheduler)
    which consult it before touching a registered owner or a pinned row.
    """

    def __init__(self) -> None:
        self._chains: dict[int, GoldenChain] = {}
        self._by_hash: dict[str, int] = {}
        self._owners: dict[int, int] = {}           # tenant -> gid
        self._forks: dict[int, tuple[int, int]] = {}  # tenant -> (gid, depth)
        self._next_gid = 0

    # -- registration ------------------------------------------------------

    def register(self, fleet, t: int, *, store=None) -> tuple[int, bool]:
        """Freeze tenant ``t``'s chain as a golden base.

        Returns ``(gid, created)``. Content-addressed: if an already
        registered chain hashes identically, its gid is returned with
        ``created=False`` and ``t`` is *not* recorded — the caller keeps
        (or frees) its duplicate and forks off the existing base.

        The tenant must be fully device-resident (``cold_count == 0``):
        a golden layer must stay hot, and registering it is what keeps
        demotion away from it afterwards. Promote first if needed.
        """
        t = int(t)
        if t in self._forks:
            raise ValueError(
                f"tenant {t} is a golden fork; it aliases another chain's "
                "rows and cannot itself be registered"
            )
        if t in self._owners:
            return self._owners[t], False
        if int(fleet.cold_count[t]) > 0:
            raise ValueError(
                f"tenant {t} holds host-tier rows; promote_tenants before "
                "registering (golden layers must stay device-resident)"
            )
        blob = migrate.export_tenant(fleet, t, store=store)
        hashes = _blob_layer_hashes(blob)
        gid = self._by_hash.get(hashes[-1])
        if gid is not None:
            return gid, False

        # rows pinned per depth: a depth-d fork aliases every device row
        # layers [0, d) reference
        entries = np.asarray(fleet.l2[t, : blob.length])
        allocm = np.asarray(fmt.entry_allocated(entries))
        zerom = np.asarray(fmt.entry_zero(entries))
        hotm = allocm & ~zerom & ~np.asarray(fmt.entry_cold(entries))
        ptrs = np.asarray(fmt.entry_ptr(entries)).astype(np.int64)
        cum, seen = [], np.zeros(0, np.int64)
        for i in range(blob.length):
            seen = np.union1d(seen, ptrs[i][hotm[i]])
            cum.append(seen)

        gid = self._next_gid
        self._next_gid += 1
        self._chains[gid] = GoldenChain(
            gid=gid,
            tenant=t,
            length=blob.length,
            layer_hashes=hashes,
            cum_rows=tuple(cum),
            layer_refs=np.zeros(blob.length, np.int64),
            fingerprint=blob.fingerprint,
        )
        self._by_hash[hashes[-1]] = gid
        self._owners[t] = gid
        return gid, True

    def unregister(self, gid: int) -> None:
        """Drop a golden chain with no live forks; the owner tenant
        becomes an ordinary (writable, demotable, freeable) tenant."""
        ch = self._chain(gid)
        if ch.fork_count:
            raise ValueError(
                f"golden chain {gid} has {ch.fork_count} live forks; "
                "free them before unregistering"
            )
        del self._chains[gid]
        del self._by_hash[ch.content_hash]
        del self._owners[ch.tenant]

    # -- fork / release ----------------------------------------------------

    def fork(self, fleet, gid: int, dst: int, *, depth: int | None = None,
             store=None):
        """Fork golden chain ``gid`` into tenant slot ``dst``: clone the
        frozen chain (optionally truncated to its first ``depth``
        layers), open a fresh active volume on top, and pin the shared
        layers. Returns the updated fleet.

        The destination slot is reset first (``free_tenant`` — pass
        ``store`` if it holds cold rows). No page data moves: the fork's
        lower layers alias the owner's pool rows under the registry's
        refcounts, which is the whole point.
        """
        ch = self._chain(gid)
        depth = ch.length if depth is None else int(depth)
        if not 1 <= depth <= ch.length:
            raise ValueError(
                f"fork depth {depth} outside [1, {ch.length}] for golden "
                f"chain {gid}"
            )
        dst = int(dst)
        if dst == ch.tenant or dst in self._owners or dst in self._forks:
            raise ValueError(
                f"tenant slot {dst} is a registered golden owner or fork; "
                "pick a free slot"
            )
        if depth + 1 > fleet.spec.max_chain:
            raise ValueError(
                f"a depth-{depth} fork needs chain room for its active "
                f"volume (max_chain={fleet.spec.max_chain}); grow the "
                "fleet geometry first"
            )
        if migrate.tenant_fingerprint(fleet, ch.tenant) != ch.fingerprint:
            raise RuntimeError(
                f"golden chain {gid}: owner tenant {ch.tenant} changed "
                "since registration — the frozen base was written, "
                "snapshotted or repacked; registry state is corrupt"
            )
        fleet = fleet_lib.free_tenant(fleet, dst, store=store,
                                      registry=self)
        fleet = fleet_lib.clone_tenant(fleet, ch.tenant, dst)
        l1 = fleet.l1.at[dst, depth:].set(0)
        l2 = fleet.l2.at[dst, depth:].set(0)
        if bool(fleet.scalable[dst]):
            # scalable (copy-forward) format: the fresh active volume is
            # a copy of the fork-point table, exactly as ``snapshot``
            # would build it
            c1, c2 = chain_lib.copy_forward_tables(l1[dst], l2[dst], depth)
            l1 = l1.at[dst].set(c1)
            l2 = l2.at[dst].set(c2)
        fleet = dataclasses.replace(
            fleet, l1=l1, l2=l2,
            length=fleet.length.at[dst].set(depth + 1),
        )
        ch.layer_refs[:depth] += 1
        self._forks[dst] = (gid, depth)
        return fleet

    def release(self, t: int) -> int:
        """Drop tenant ``t``'s pin on its golden base (the fork is being
        freed or migrated away). Returns the gid it pinned."""
        gid, depth = self._forks.pop(int(t))
        self._chains[gid].layer_refs[:depth] -= 1
        return gid

    # -- queries (consulted by the lifecycle/maintenance ops) --------------

    def _chain(self, gid: int) -> GoldenChain:
        if gid not in self._chains:
            raise KeyError(f"unknown golden chain id {gid}")
        return self._chains[gid]

    def lookup(self, content_hash: str) -> int | None:
        """gid registered under ``content_hash``, or None."""
        return self._by_hash.get(content_hash)

    def is_golden_owner(self, t: int) -> bool:
        return int(t) in self._owners

    def is_fork(self, t: int) -> bool:
        return int(t) in self._forks

    def gid_of(self, t: int) -> int | None:
        """gid tenant ``t`` owns or pins, or None."""
        t = int(t)
        if t in self._owners:
            return self._owners[t]
        if t in self._forks:
            return self._forks[t][0]
        return None

    def golden_owner_mask(self, n_tenants: int) -> np.ndarray:
        """(T,) bool — tenants whose chains are frozen golden bases."""
        mask = np.zeros(n_tenants, bool)
        if self._owners:
            mask[list(self._owners)] = True
        return mask

    def pinned_rows(self) -> np.ndarray:
        """Every device row some registered chain freezes (sorted).

        The maintenance plane treats these as immovable: excluded from
        repack relocation and from demotion picks while registered.
        """
        if not self._chains:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(
            [ch.rows for ch in self._chains.values()]
        ))

    def shared_rows_for(self, t: int) -> np.ndarray | None:
        """Rows tenant ``t`` legally aliases: the pinned rows of the
        golden layers it forked (None if ``t`` is not a fork)."""
        rec = self._forks.get(int(t))
        if rec is None:
            return None
        gid, depth = rec
        return self._chains[gid].cum_rows[depth - 1]

    def stats(self) -> dict:
        """Registry-level dedup accounting. ``dedup_rows_saved`` is the
        device rows forks alias instead of copying — the capacity the
        golden plane returns to the pool."""
        saved = sum(
            int(self._chains[gid].cum_rows[depth - 1].size)
            for gid, depth in self._forks.values()
        )
        return dict(
            golden_chains=len(self._chains),
            golden_forks=len(self._forks),
            golden_rows_pinned=int(self.pinned_rows().size),
            dedup_rows_saved=saved,
        )

    # -- self-check (run from core.invariants) -----------------------------

    def check(self, fl) -> None:
        """Assert registry/fleet agreement: frozen owners unchanged,
        pinned rows still inside their owner's leases, per-layer pins
        consistent with the recorded forks."""
        q = fl.spec.lease_quantum
        owner = np.asarray(fl.lease_owner)
        want_refs = {gid: np.zeros(ch.length, np.int64)
                     for gid, ch in self._chains.items()}
        for t, (gid, depth) in self._forks.items():
            assert gid in self._chains, \
                f"fork tenant {t} pins unknown golden chain {gid}"
            want_refs[gid][:depth] += 1
        for gid, ch in self._chains.items():
            assert self._owners.get(ch.tenant) == gid, \
                f"golden chain {gid} owner bookkeeping drifted"
            fp = migrate.tenant_fingerprint(fl, ch.tenant)
            assert fp == ch.fingerprint, (
                f"golden chain {gid}: owner tenant {ch.tenant} mutated "
                "while registered (write/snapshot/repack on a frozen base)"
            )
            assert np.array_equal(ch.layer_refs, want_refs[gid]), (
                f"golden chain {gid}: layer refcounts "
                f"{ch.layer_refs.tolist()} disagree with live forks"
            )
            if ch.rows.size:
                assert (owner[ch.rows // q] == ch.tenant).all(), (
                    f"golden chain {gid}: pinned rows left owner tenant "
                    f"{ch.tenant}'s leases"
                )


# -- serving-plane prefix lookup ---------------------------------------------


class _TrieNode:
    __slots__ = ("edges", "value")

    def __init__(self) -> None:
        self.edges: dict[int, tuple[tuple[int, ...], _TrieNode]] = {}
        self.value: object | None = None


class PrefixTrie:
    """Radix-style (path-compressed) prefix lookup over token ids.

    Maps registered token sequences to an opaque value (the serving
    plane stores the golden sequence id). ``longest_prefix`` returns the
    deepest *registered* sequence that prefixes a query — admission
    forks that golden chain and prefills only the suffix. Edges are
    compressed token runs, so lookup cost scales with the number of
    distinct branch points, not prompt length times fanout.
    """

    def __init__(self) -> None:
        self._root = _TrieNode()
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def insert(self, tokens, value) -> None:
        """Register ``tokens`` (non-empty int sequence) -> ``value``."""
        key = tuple(int(t) for t in tokens)
        if not key:
            raise ValueError("cannot register an empty token sequence")
        node, i = self._root, 0
        while i < len(key):
            edge = node.edges.get(key[i])
            if edge is None:
                leaf = _TrieNode()
                node.edges[key[i]] = (key[i:], leaf)
                node, i = leaf, len(key)
                continue
            run, child = edge
            common = _common_len(run, key[i:])
            if common == len(run):
                node, i = child, i + common
                continue
            # split the edge at the divergence point
            mid = _TrieNode()
            mid.edges[run[common]] = (run[common:], child)
            node.edges[key[i]] = (run[:common], mid)
            node, i = mid, i + common
        if node.value is not None and node.value != value:
            raise ValueError("token sequence already registered")
        if node.value is None:
            self._len += 1
        node.value = value

    def longest_prefix(self, tokens):
        """Deepest registered sequence prefixing ``tokens``:
        ``(match_len, value)`` or ``(0, None)``."""
        key = tuple(int(t) for t in tokens)
        node, i = self._root, 0
        best_len, best_val = 0, None
        if node.value is not None:   # pragma: no cover - empty keys banned
            best_len, best_val = i, node.value
        while i < len(key):
            edge = node.edges.get(key[i])
            if edge is None:
                break
            run, child = edge
            if _common_len(run, key[i:]) < len(run):
                break
            node, i = child, i + len(run)
            if node.value is not None:
                best_len, best_val = i, node.value
        return best_len, best_val

    def remove(self, tokens) -> None:
        """Unregister ``tokens`` (must be registered). Collapses nodes
        lazily: emptied leaves are pruned, single-child pass-through
        nodes are left (harmless for lookup correctness)."""
        key = tuple(int(t) for t in tokens)
        path: list[tuple[_TrieNode, int]] = []
        node, i = self._root, 0
        while i < len(key):
            edge = node.edges.get(key[i])
            if edge is None:
                raise KeyError("token sequence not registered")
            run, child = edge
            if key[i:i + len(run)] != run:
                raise KeyError("token sequence not registered")
            path.append((node, key[i]))
            node, i = child, i + len(run)
        if node.value is None:
            raise KeyError("token sequence not registered")
        node.value = None
        self._len -= 1
        while path and node.value is None and not node.edges:
            parent, tok = path.pop()
            del parent.edges[tok]
            node = parent


def _common_len(a: tuple, b: tuple) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n
