"""SnapStore core: JAX-native COW snapshot-chain state management.

The paper's contribution (sQEMU: direct access + unified indexing cache +
snapshot copy-forward) as a composable JAX module. See DESIGN.md.
"""

from repro.core import format  # noqa: F401
from repro.core.chain import Chain, ChainSpec, create, snapshot, stream, write  # noqa: F401
from repro.core.resolve import (  # noqa: F401
    ResolveResult,
    get_resolver,
    resolve_auto,
    resolve_direct,
    resolve_vanilla,
)
from repro.core import cache, fleet, golden, metrics, scheduler, store  # noqa: F401
from repro.core.fleet import ChainFleet, FleetSpec  # noqa: F401
from repro.core.golden import GoldenRegistry, PrefixTrie  # noqa: F401
from repro.core.scheduler import MaintenanceScheduler  # noqa: F401
