"""VirtualTensorStore: the user-facing COW snapshot store.

High-level API over ``chain.py``/``resolve.py``: whole-page reads and
writes with copy-on-write semantics, snapshotting, streaming compaction and
chain-length accounting. Everything on the read/write path is jittable; the
maintenance path (streaming, conversion) is host-side, as in Qemu.

This is the substrate both integrations build on:

* ``repro.checkpoint`` stores training state as pages and snapshots the
  store at every checkpoint — an incremental (delta) checkpoint chain;
* ``repro.kvcache`` stores KV pages and snapshots at sequence-fork points —
  a prefix-sharing chain.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import chain as chain_lib
from repro.core import resolve as resolve_lib
from repro.core.chain import Chain, ChainSpec


def gather_pages(pool: jax.Array, res: resolve_lib.ResolveResult) -> jax.Array:
    """Gather resolved pages from a pool; unallocated/ZERO read as zeros.

    Shape-polymorphic over leading batch axes: serves both the single-chain
    ``read`` ((B,) results) and the fleet's batched read ((T, B) results —
    the pool is global, so one gather covers every tenant).
    """
    rows = jnp.where(res.found & ~res.zero, res.ptr, 0).astype(jnp.int32)
    data = pool[rows]
    ok = (res.found & ~res.zero)[..., None]
    return jnp.where(ok, data, jnp.zeros_like(data))


@partial(jax.jit, static_argnames=("method",))
def read(chain: Chain, page_ids: jax.Array, *, method: str = "auto"):
    """Read whole pages. Unallocated or ZERO pages read as zeros.

    Returns ``(data (B, page_size), ResolveResult)``.
    """
    res = resolve_lib.get_resolver(method)(chain, page_ids)
    return gather_pages(chain.pool, res), res


write = chain_lib.write
snapshot = chain_lib.snapshot
stream = chain_lib.stream
compact_pool = chain_lib.compact_pool
convert_to_scalable = chain_lib.convert_to_scalable


def create(
    n_pages: int,
    page_size: int,
    *,
    max_chain: int = 64,
    pool_capacity: int | None = None,
    scalable: bool = True,
    dtype=jnp.float32,
    l2_per_table: int = 64,
    slice_len: int = 16,
) -> Chain:
    """Convenience constructor with sane defaults for tests/examples."""
    if pool_capacity is None:
        pool_capacity = 4 * n_pages
    spec = ChainSpec(
        n_pages=n_pages,
        page_size=page_size,
        max_chain=max_chain,
        pool_capacity=pool_capacity,
        l2_per_table=l2_per_table,
        slice_len=slice_len,
        dtype=dtype,
    )
    return chain_lib.create(spec, scalable=scalable)


def chain_length(chain: Chain) -> int:
    return int(chain.length)


def allocated_mask(chain: Chain, *, method: str = "auto") -> jax.Array:
    """(n_pages,) bool: which logical pages currently hold data."""
    ids = jnp.arange(chain.spec.n_pages, dtype=jnp.int32)
    res = resolve_lib.get_resolver(method)(chain, ids)
    return res.found


def materialize(chain: Chain, *, method: str = "auto") -> jax.Array:
    """Read the full virtual disk: (n_pages, page_size). The 'dd' op."""
    ids = jnp.arange(chain.spec.n_pages, dtype=jnp.int32)
    data, _ = read(chain, ids, method=method)
    return data


def check_pool_capacity(chain: Chain) -> None:
    """Raise if the chain hit a resource limit (host-side guard)."""
    if bool(chain.overflow):
        raise RuntimeError(
            "page pool overflow: grow ChainSpec.pool_capacity or stream "
            "the chain"
        )
    if bool(chain.snap_dropped):
        raise RuntimeError(
            "snapshot dropped: the chain is at max_chain; stream() to "
            "shorten it (the flag clears only if streaming actually makes "
            "room — a merge_upto=0 stream shortens nothing and leaves it "
            "latched)"
        )
