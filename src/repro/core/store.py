"""VirtualTensorStore: the user-facing COW snapshot store.

High-level API over ``chain.py``/``resolve.py``: whole-page reads and
writes with copy-on-write semantics, snapshotting, streaming compaction and
chain-length accounting. Everything on the read/write path is jittable; the
maintenance path (streaming, conversion) is host-side, as in Qemu.

This is the substrate both integrations build on:

* ``repro.checkpoint`` stores training state as pages and snapshots the
  store at every checkpoint — an incremental (delta) checkpoint chain;
* ``repro.kvcache`` stores KV pages and snapshots at sequence-fork points —
  a prefix-sharing chain.

``TieredStore`` is the second tier behind the device pool: a host (numpy)
page array that cold snapshot layers are demoted into by the maintenance
plane (``fleet.demote_tenants``), addressed by the same 28-bit ``ptr``
field under the ``FLAG_COLD`` residency bit. See ``docs/memory.md`` for
the end-to-end memory model.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain as chain_lib
from repro.core import format as fmt
from repro.core import resolve as resolve_lib
from repro.core.chain import Chain, ChainSpec


def gather_pages(pool: jax.Array, res: resolve_lib.ResolveResult) -> jax.Array:
    """Gather resolved pages from a pool; unallocated/ZERO read as zeros.

    Cold hits (``res.cold`` — pages demoted to the host tier) also read as
    zeros here: their ``ptr`` addresses the ``TieredStore`` host pool, not
    the device pool, so dereferencing it would alias an unrelated row.
    Callers that need cold data promote first (``fleet.promote_tenants``)
    or read through ``fleet.read_tiered``.

    Shape-polymorphic over leading batch axes: serves both the single-chain
    ``read`` ((B,) results) and the fleet's batched read ((T, B) results —
    the pool is global, so one gather covers every tenant).
    """
    ok = res.found & ~res.zero & ~res.cold
    rows = jnp.where(ok, res.ptr, 0).astype(jnp.int32)
    data = pool[rows]
    return jnp.where(ok[..., None], data, jnp.zeros_like(data))


class TieredStore:
    """The host (numpy) cold tier behind a fleet's device page pool.

    A flat page array with its own row allocator: ``fleet.demote_tenants``
    copies whole immutable snapshot layers out of the device pool into
    host rows allocated here and rewrites the evicted L2 entries to
    ``(host_row | FLAG_COLD)``; ``fleet.promote_tenants`` moves them back
    and returns the host rows to this free list. Rows are addressed by
    the entry's 28-bit ``ptr`` field, so the two tiers share one pointer
    format and an entry's ``(cold, ptr)`` pair is a complete address.

    Capacity grows by doubling on demand (host DRAM is the cheap tier;
    the device pool is the budgeted one). All methods are host-side, like
    the rest of the maintenance plane. Lifetime transfer counters
    (``demoted_rows``/``promoted_rows``) feed ``metrics.tier_residency``.
    """

    def __init__(self, page_size: int, dtype=jnp.float32, *,
                 initial_rows: int = 0):
        self.page_size = int(page_size)
        self.dtype = dtype
        cap = max(int(initial_rows), 1)
        self._data = np.zeros((cap, self.page_size), np.dtype(dtype))
        self._free: list[int] = []
        self._top = 0            # high-water mark of ever-allocated rows
        self.demoted_rows = 0    # lifetime pages moved device -> host
        self.promoted_rows = 0   # lifetime pages moved host -> device

    @classmethod
    def for_fleet(cls, spec) -> "TieredStore":
        """A cold tier matching a ``FleetSpec``'s page geometry."""
        return cls(spec.page_size, spec.dtype,
                   initial_rows=spec.pool_capacity)

    def host_rows_in_use(self) -> int:
        return self._top - len(self._free)

    def alloc(self, n: int) -> np.ndarray:
        """Allocate ``n`` host rows; returns their ids (int64, sorted-ish).

        Free-listed rows are reused first; fresh rows extend the array
        (doubling). Raises if a row id would not fit the 28-bit ``ptr``
        field — the shared pointer format is the one hard capacity limit.
        """
        take = min(n, len(self._free))
        rows = [self._free.pop() for _ in range(take)]
        fresh = n - take
        if fresh:
            if self._top + fresh > fmt.MAX_POOL_ROWS:
                raise RuntimeError(
                    "host tier exhausted: row ids no longer fit the "
                    "28-bit ptr field"
                )
            while self._data.shape[0] < self._top + fresh:
                grown = np.zeros((self._data.shape[0] * 2, self.page_size),
                                 self._data.dtype)
                grown[: self._data.shape[0]] = self._data
                self._data = grown
            rows.extend(range(self._top, self._top + fresh))
            self._top += fresh
        return np.asarray(rows, np.int64)

    def put(self, rows: np.ndarray, data: np.ndarray) -> None:
        """Fill host rows (a demotion's data movement)."""
        rows = np.asarray(rows, np.int64)
        self._data[rows] = np.asarray(data, self._data.dtype)
        self.demoted_rows += int(rows.size)

    def get(self, rows: np.ndarray) -> np.ndarray:
        """Read host rows (a promotion's source, or a tiered read)."""
        return self._data[np.asarray(rows, np.int64)]

    def free(self, rows: np.ndarray) -> None:
        """Return host rows to the free list (promotion / tenant free)."""
        rows = np.atleast_1d(np.asarray(rows, np.int64))
        if rows.size and (np.min(rows) < 0 or np.max(rows) >= self._top):
            raise ValueError("freeing host rows that were never allocated")
        self._free.extend(int(r) for r in rows)

    def clone(self) -> "TieredStore":
        """An isolated copy sharing no state with ``self``.

        Unlike fleets, the store is mutable host state — any flow that
        wants to speculate against it (a migration dry-run, a test
        branching one grown fixture into independent futures) must fork
        it first or later frees corrupt the shared free list.
        """
        out = TieredStore(self.page_size, self.dtype,
                          initial_rows=self._data.shape[0])
        out._data = self._data.copy()
        out._free = list(self._free)
        out._top = self._top
        out.demoted_rows = self.demoted_rows
        out.promoted_rows = self.promoted_rows
        return out

    def stats(self) -> dict:
        return dict(
            host_rows_in_use=self.host_rows_in_use(),
            host_rows_capacity=int(self._data.shape[0]),
            demoted_rows=self.demoted_rows,
            promoted_rows=self.promoted_rows,
        )


@partial(jax.jit, static_argnames=("method",))
def read(chain: Chain, page_ids: jax.Array, *, method: str = "auto"):
    """Read whole pages. Unallocated or ZERO pages read as zeros.

    Returns ``(data (B, page_size), ResolveResult)``.
    """
    res = resolve_lib.get_resolver(method)(chain, page_ids)
    return gather_pages(chain.pool, res), res


write = chain_lib.write
snapshot = chain_lib.snapshot
stream = chain_lib.stream
compact_pool = chain_lib.compact_pool
convert_to_scalable = chain_lib.convert_to_scalable


def create(
    n_pages: int,
    page_size: int,
    *,
    max_chain: int = 64,
    pool_capacity: int | None = None,
    scalable: bool = True,
    dtype=jnp.float32,
    l2_per_table: int = 64,
    slice_len: int = 16,
) -> Chain:
    """Convenience constructor with sane defaults for tests/examples."""
    if pool_capacity is None:
        pool_capacity = 4 * n_pages
    spec = ChainSpec(
        n_pages=n_pages,
        page_size=page_size,
        max_chain=max_chain,
        pool_capacity=pool_capacity,
        l2_per_table=l2_per_table,
        slice_len=slice_len,
        dtype=dtype,
    )
    return chain_lib.create(spec, scalable=scalable)


def chain_length(chain: Chain) -> int:
    return int(chain.length)


def allocated_mask(chain: Chain, *, method: str = "auto") -> jax.Array:
    """(n_pages,) bool: which logical pages currently hold data."""
    ids = jnp.arange(chain.spec.n_pages, dtype=jnp.int32)
    res = resolve_lib.get_resolver(method)(chain, ids)
    return res.found


def materialize(chain: Chain, *, method: str = "auto") -> jax.Array:
    """Read the full virtual disk: (n_pages, page_size). The 'dd' op."""
    ids = jnp.arange(chain.spec.n_pages, dtype=jnp.int32)
    data, _ = read(chain, ids, method=method)
    return data


def check_pool_capacity(chain: Chain) -> None:
    """Raise if the chain hit a resource limit (host-side guard)."""
    if bool(chain.overflow):
        raise RuntimeError(
            "page pool overflow: grow ChainSpec.pool_capacity or stream "
            "the chain"
        )
    if bool(chain.snap_dropped):
        raise RuntimeError(
            "snapshot dropped: the chain is at max_chain; stream() to "
            "shorten it (the flag clears only if streaming actually makes "
            "room — a merge_upto=0 stream shortens nothing and leaves it "
            "latched)"
        )
