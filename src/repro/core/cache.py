"""L2 indexing-cache model: per-file caches (vQemu) vs unified (sQEMU).

The *production* read path of SnapStore resolves pages with pure gathers
(``resolve.py``/``kernels/``) — HBM is the only "disk" on a TPU. This module
exists to reproduce the paper's **low-level metrics** (Fig 13: cache misses,
cache hits unallocated, per-file lookup distribution; Fig 14: lookup
latency; Fig 16: cache-size sensitivity): it simulates the Qcow2 slice
cache exactly as §2 of the paper describes it — slice-granular, fully
associative, LRU — sequentially over a request stream, in jitted
``lax.scan`` form.

Event accounting follows the paper's definitions:

* **cache miss** — the slice holding the request's L2 entry is not in the
  (relevant) cache and must be fetched from the file (one T_D + T_L cost);
* **cache hit** — the cached entry describes an allocated page;
* **cache hit unallocated** — the cached entry is unallocated, so vQemu
  moves on to the next backing file's cache (one T_F cost per event).

Under vQemu a single request generates up to ``chain_length`` misses and
hit-unallocated events (the chain walk); under sQEMU each request touches
exactly one cache, and the entry's ``backing_file_index`` makes it directly
usable even when the data lives in a backing file (``backing_reads``
counts those). Memory: vQemu allocates one cache per file at boot;
sQEMU's unified cache is O(1) in the chain length (Fig 12).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from functools import partial

from repro.core import format as fmt
from repro.core.chain import Chain, ChainSpec


class SimTrace(NamedTuple):
    """Per-request event counts from a cache simulation (shape (R,))."""

    probes: jax.Array           # cache lookups performed
    misses: jax.Array           # slice fetches from "disk"
    hits: jax.Array             # allocated-entry hits
    hit_unallocated: jax.Array  # unallocated-entry events
    backing_reads: jax.Array    # data reads served by a backing file
    hist: jax.Array             # (max_chain,) lookups by owning file


def cache_memory_bytes(
    spec: ChainSpec,
    n_slots: int,
    chain_length: int,
    *,
    unified: bool,
    per_snapshot_overhead: int = 256,
) -> int:
    """Index-cache RAM model (Fig 12).

    vQemu allocates one slice cache per file in the chain at boot; sQEMU
    keeps a single one. ``per_snapshot_overhead`` models the residual
    per-snapshot driver structures the paper observes even under sQEMU
    (§6.2: "other per-snapshot data structures").
    """
    slice_bytes = spec.slice_len * fmt.ENTRY_WORDS * 4
    slot_bytes = slice_bytes + 16  # tag + ref + dirty + lru bookkeeping
    one_cache = n_slots * slot_bytes
    caches = 1 if unified else chain_length
    return caches * one_cache + chain_length * per_snapshot_overhead


def cache_correction(sv_entries: jax.Array, sb_entries: jax.Array) -> jax.Array:
    """Paper §5.3 "cache correction": merge backing slice ``sb`` into the
    cached slice ``sv``.

    An entry of ``sv`` is replaced by the corresponding ``sb`` entry iff
    ``sb`` is allocated and its ``backing_file_index`` is >= that of the
    ``sv`` entry (or ``sv`` is unallocated). Monotone in bfi and
    idempotent — properties checked by the test suite.
    """
    sb_alloc = fmt.entry_allocated(sb_entries)
    sv_alloc = fmt.entry_allocated(sv_entries)
    newer = fmt.entry_bfi(sb_entries) >= fmt.entry_bfi(sv_entries)
    replace = sb_alloc & (~sv_alloc | newer)
    return jnp.where(replace[..., None], sb_entries, sv_entries)


@partial(jax.jit, static_argnames=("n_slots",))
def simulate_vanilla(chain: Chain, page_ids: jax.Array, n_slots: int) -> SimTrace:
    """Sequentially simulate the vQemu per-file caches over a request stream.

    Each request walks the chain from the active volume down to the owning
    file, probing (and on miss, filling) one cache per file visited.
    """
    spec = chain.spec
    C = spec.max_chain
    page_ids = page_ids.astype(jnp.int32)
    chain_idx = jnp.arange(C, dtype=jnp.int32)
    active = chain.length - 1

    def step(carry, p):
        tags, age, t = carry
        slice_id = p // spec.slice_len
        table_id = p // spec.l2_per_table

        entries = chain.l2[:, p]                              # (C, 2)
        alloc = fmt.entry_allocated(entries) & (chain_idx < chain.length)
        owner = jnp.max(jnp.where(alloc, chain_idx, -1))
        found = owner >= 0
        low = jnp.where(found, owner, 0)
        probed = (chain_idx >= low) & (chain_idx <= active)    # files visited
        on_disk = chain.l1[:, table_id] > 0                    # slice exists

        match = tags == slice_id                               # (C, S)
        in_cache = jnp.any(match, axis=1)                      # (C,)
        fetch = probed & ~in_cache & on_disk
        n_probes = jnp.sum(probed.astype(jnp.int32))
        n_miss = jnp.sum(fetch.astype(jnp.int32))
        n_unal = jnp.sum((probed & on_disk).astype(jnp.int32)) - jnp.where(
            found & on_disk[jnp.maximum(owner, 0)], 1, 0
        )
        n_hit = found.astype(jnp.int32)

        # LRU touch for probe hits; insert (evicting LRU) for fetches.
        t = t + 1
        touch = match & (probed & in_cache)[:, None]
        age = jnp.where(touch, t, age)
        slot = jnp.argmin(age, axis=1)                         # (C,)
        onehot = jax.nn.one_hot(slot, n_slots, dtype=bool)
        upd = fetch[:, None] & onehot
        tags = jnp.where(upd, slice_id, tags)
        age = jnp.where(upd, t, age)

        hist_r = probed.astype(jnp.int32)
        out = (n_probes, n_miss, n_hit, n_unal, jnp.int32(0), hist_r)
        return (tags, age, t), out

    tags0 = jnp.full((C, n_slots), -1, jnp.int32)
    age0 = jnp.full((C, n_slots), -1, jnp.int32)
    (_, _, _), (probes, misses, hits, unal, backing, hist) = jax.lax.scan(
        step, (tags0, age0, jnp.int32(0)), page_ids
    )
    return SimTrace(probes, misses, hits, unal, backing, jnp.sum(hist, axis=0))


@partial(jax.jit, static_argnames=("n_slots",))
def simulate_unified(chain: Chain, page_ids: jax.Array, n_slots: int) -> SimTrace:
    """Sequentially simulate the sQEMU unified cache over a request stream.

    One probe per request; the active volume's copied-forward L2 entry is
    directly usable (ptr + backing_file_index), so data living in a backing
    file costs a ``backing_read`` but never a chain walk.
    """
    spec = chain.spec
    page_ids = page_ids.astype(jnp.int32)
    active = chain.length - 1

    def step(carry, p):
        tags, age, t = carry
        slice_id = p // spec.slice_len

        entry = chain.l2[active, p]                            # (2,)
        alloc = fmt.entry_allocated(entry[None])[0]
        bfi = fmt.entry_bfi(entry[None])[0].astype(jnp.int32)

        match = tags == slice_id                               # (S,)
        in_cache = jnp.any(match)
        n_miss = (~in_cache).astype(jnp.int32)
        n_hit = alloc.astype(jnp.int32)
        n_unal = (~alloc).astype(jnp.int32)
        backing = (alloc & (bfi != active)).astype(jnp.int32)

        t = t + 1
        age = jnp.where(match & in_cache, t, age)
        slot = jnp.argmin(age)
        tags = jnp.where(
            ~in_cache, tags.at[slot].set(slice_id), tags
        )
        age = jnp.where(~in_cache, age.at[slot].set(t), age)

        hist_r = jax.nn.one_hot(
            jnp.where(alloc, bfi, active), spec.max_chain, dtype=jnp.int32
        )
        out = (jnp.int32(1), n_miss, n_hit, n_unal, backing, hist_r)
        return (tags, age, t), out

    tags0 = jnp.full((n_slots,), -1, jnp.int32)
    age0 = jnp.full((n_slots,), -1, jnp.int32)
    (_, _, _), (probes, misses, hits, unal, backing, hist) = jax.lax.scan(
        step, (tags0, age0, jnp.int32(0)), page_ids
    )
    return SimTrace(probes, misses, hits, unal, backing, jnp.sum(hist, axis=0))


def summarize(trace: SimTrace) -> dict:
    return dict(
        probes=int(jnp.sum(trace.probes)),
        misses=int(jnp.sum(trace.misses)),
        hits=int(jnp.sum(trace.hits)),
        hit_unallocated=int(jnp.sum(trace.hit_unallocated)),
        backing_reads=int(jnp.sum(trace.backing_reads)),
    )
