"""Tenant live-migration: export → detach → attach, bit-identically.

The provider fleet rebalances by *moving* snapshot chains between hosts
(the Aquifer/FlexBSO primitive): a tenant's entire chain — L1/L2 words,
the leased device pool pages its hot entries reference, the host-tier
pages its ``FLAG_COLD`` entries reference — is packed into a
self-contained portable blob, freed on the source fleet, and installed
on a destination fleet that may have completely different pool geometry
and lease state.

**Blob format.** The blob carries the chain exactly as the guest sees
it, with pointers *localized*:

* ``l1`` — the tenant's L1 stack, verbatim (layer-relative, geometry-
  independent).
* ``l2`` — the tenant's L2 stack with every hot pointer rewritten to an
  index into ``hot_pages`` and every COLD pointer to an index into
  ``cold_pages``. All flag bits (ALLOCATED/ZERO/COLD/ENCRYPTED) and the
  backing-file-index word travel untouched — ``FLAG_COLD`` remains the
  hot/cold discriminator, so residency survives the move.
* ``hot_pages`` / ``cold_pages`` — the referenced device/host rows'
  data, deduplicated (scalable-format chains alias one row from many
  entries; the blob stores it once).
* ``fingerprint`` — a digest of the tenant's source state at export
  time, the mid-flight write guard (below).

Serialization to disk reuses the checkpoint plane's container
(``checkpoint/snapstore_ckpt.py`` idiom: one compressed ``.npz``, numpy
arrays only, no pickle).

**Detach/attach lifecycle.** ``export_tenant`` is pure read. The source
stays writable during export; ``detach_tenant`` recomputes the
fingerprint and refuses (``MigrationError``) if *anything* about the
tenant changed since the blob was cut — a write, snapshot, stream,
compact or demotion landing mid-migration means the blob is stale, and
the migration must restart from a fresh export. On success detach is
``free_tenant``: leases back to the allocator, host rows back to the
store. ``import_tenant`` resets the destination slot, acquires exactly
the rows it needs through the destination's own lease allocator
(``acquire_rows``), re-allocates cold rows from the destination's own
``TieredStore``, delocalizes the pointers, and installs the chain
(``install_tenant``). ``migrate_tenant`` strings these together and
bit-verifies source against destination (``read_tiered`` over every
page) *before* detaching — the source is never dropped until the
destination provably serves identical bytes.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import fleet as fleet_lib
from repro.core import format as fmt


class MigrationError(RuntimeError):
    """A migration step refused: stale export, geometry mismatch, or a
    destination that failed bit-verification."""


# -- fingerprint: the mid-flight write guard ---------------------------------


def tenant_fingerprint(fleet, t: int) -> str:
    """Digest of everything about tenant ``t`` that an op could change.

    Covers the L1/L2 stacks (so any write, snapshot, stream, compact,
    demote or promote changes it — maintenance repacks rewrite pointers
    even when data is preserved, and the conservative guard treats that
    as staleness too), plus the scalar per-tenant state.
    """
    length = int(fleet.length[t])
    h = hashlib.sha256()
    h.update(np.asarray(fleet.l1[t, :length]).tobytes())
    h.update(np.asarray(fleet.l2[t, :length]).tobytes())
    h.update(np.asarray(
        [length, int(fleet.alloc_count[t]), int(fleet.cold_count[t]),
         int(bool(fleet.scalable[t]))], np.int64
    ).tobytes())
    return h.hexdigest()


# -- the portable blob -------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantBlob:
    """A tenant's chain, packed self-contained and geometry-localized."""

    n_pages: int
    page_size: int
    l2_per_table: int
    dtype: str               # numpy dtype name of the page payloads
    length: int
    scalable: bool
    l1: np.ndarray           # (length, n_l1) uint32, verbatim
    l2: np.ndarray           # (length, n_pages, 2) uint32, ptrs localized
    hot_pages: np.ndarray    # (n_hot, page_size) — referenced device rows
    cold_pages: np.ndarray   # (n_cold, page_size) — referenced host rows
    fingerprint: str         # source state at export time (detach guard)

    @property
    def n_hot(self) -> int:
        return self.hot_pages.shape[0]

    @property
    def n_cold(self) -> int:
        return self.cold_pages.shape[0]

    def nbytes(self) -> int:
        return (self.l1.nbytes + self.l2.nbytes
                + self.hot_pages.nbytes + self.cold_pages.nbytes)


def _entry_masks(l2: np.ndarray):
    """(allocated&data hot, allocated&data cold) masks for an L2 stack."""
    allocm = np.asarray(fmt.entry_allocated(l2))
    zerom = np.asarray(fmt.entry_zero(l2))
    coldm = np.asarray(fmt.entry_cold(l2))
    data = allocm & ~zerom
    return data & ~coldm, data & coldm


def _rewrite_ptrs(l2: np.ndarray, mask: np.ndarray,
                  new_ptrs: np.ndarray) -> np.ndarray:
    """Replace the pointer field of the masked entries, flags untouched."""
    out = l2.copy()
    w0 = out[..., 0]
    w0[mask] = ((w0[mask] & ~np.uint32(fmt.PTR_MASK))
                | new_ptrs.astype(np.uint32))
    return out


# -- export ------------------------------------------------------------------


def export_tenant(fleet, t: int, *, store=None) -> TenantBlob:
    """Pack tenant ``t`` into a portable blob. Pure read — the source
    fleet is untouched and stays writable (``detach_tenant`` catches any
    write that lands in the window).

    ``store`` is required iff the tenant holds demoted (cold) layers:
    their host-tier pages ride along in the blob.
    """
    spec = fleet.spec
    length = int(fleet.length[t])
    l1 = np.array(fleet.l1[t, :length])
    l2 = np.array(fleet.l2[t, :length])
    hotm, coldm = _entry_masks(l2)
    ptrs = np.asarray(fmt.entry_ptr(l2)).astype(np.int64)

    hot_rows = np.unique(ptrs[hotm])
    cold_rows = np.unique(ptrs[coldm])
    if cold_rows.size and store is None:
        raise MigrationError(
            f"tenant {t} holds {cold_rows.size} host-tier rows; pass the "
            "TieredStore so export can pack its cold pages"
        )

    if hot_rows.size:
        hot_pages = np.asarray(fleet.pool[hot_rows])
    else:
        hot_pages = np.zeros((0, spec.page_size), np.dtype(spec.dtype))
    if cold_rows.size:
        cold_pages = np.asarray(store.get(cold_rows))
    else:
        cold_pages = np.zeros((0, spec.page_size), np.dtype(spec.dtype))

    # localize: pointer -> dense index into the blob's page tables
    l2_local = _rewrite_ptrs(l2, hotm, np.searchsorted(hot_rows, ptrs[hotm]))
    l2_local = _rewrite_ptrs(l2_local, coldm,
                             np.searchsorted(cold_rows, ptrs[coldm]))

    return TenantBlob(
        n_pages=spec.n_pages,
        page_size=spec.page_size,
        l2_per_table=spec.l2_per_table,
        dtype=np.dtype(spec.dtype).name,
        length=length,
        scalable=bool(fleet.scalable[t]),
        l1=l1,
        l2=l2_local,
        hot_pages=hot_pages,
        cold_pages=cold_pages,
        fingerprint=tenant_fingerprint(fleet, t),
    )


# -- attach ------------------------------------------------------------------


def _check_geometry(spec, blob: TenantBlob) -> None:
    """The destination must agree on the *guest-visible* geometry; pool
    capacity, lease quantum, tenant count and spare chain depth are the
    host's business and may all differ."""
    mismatches = [
        name for name, got, want in [
            ("n_pages", spec.n_pages, blob.n_pages),
            ("page_size", spec.page_size, blob.page_size),
            ("l2_per_table", spec.l2_per_table, blob.l2_per_table),
            ("dtype", np.dtype(spec.dtype).name, blob.dtype),
        ] if got != want
    ]
    if mismatches:
        raise MigrationError(
            "destination fleet disagrees on guest-visible geometry: "
            + ", ".join(mismatches)
        )
    if blob.length > spec.max_chain:
        raise MigrationError(
            f"blob chain depth {blob.length} exceeds destination "
            f"max_chain={spec.max_chain}"
        )


def import_tenant(fleet, t: int, blob: TenantBlob, *, store=None):
    """Attach a blob into slot ``t`` of the destination fleet.

    The slot is reset first (``free_tenant`` — a previous occupant's
    leases and host rows are returned), hot rows are granted through the
    destination's lease allocator and cold rows through its store, and
    the blob's localized pointers are rewritten to the new rows. Raises
    ``MigrationError`` on geometry mismatch, ``RuntimeError`` if the
    destination pool cannot grant ``blob.n_hot`` rows.
    """
    _check_geometry(fleet.spec, blob)
    if blob.n_cold and store is None:
        raise MigrationError(
            f"blob carries {blob.n_cold} cold pages; pass the destination "
            "TieredStore to land them"
        )
    fleet = fleet_lib.free_tenant(fleet, t, store=store)
    fleet, dev_rows = fleet_lib.acquire_rows(fleet, t, blob.n_hot)
    host_rows = np.zeros(0, np.int64)
    if blob.n_cold:
        host_rows = store.alloc(blob.n_cold)
        store.put(host_rows, blob.cold_pages)

    l2 = blob.l2
    hotm, coldm = _entry_masks(l2)
    local = np.asarray(fmt.entry_ptr(l2)).astype(np.int64)
    l2 = _rewrite_ptrs(l2, hotm, dev_rows[local[hotm]])
    if blob.n_cold:
        l2 = _rewrite_ptrs(l2, coldm, host_rows[local[coldm]])

    return fleet_lib.install_tenant(
        fleet, t,
        l1=blob.l1, l2=l2, length=blob.length, scalable=blob.scalable,
        cold_count=blob.n_cold, pool_rows=dev_rows, pool_data=blob.hot_pages,
    )


def detach_tenant(fleet, t: int, blob: TenantBlob, *, store=None,
                  registry=None):
    """Release tenant ``t`` from the source fleet — the commit point of a
    migration. Refuses with ``MigrationError`` if the tenant's state no
    longer matches ``blob`` (a write/snapshot/maintenance op landed after
    export): the blob is stale and must be re-exported.

    ``registry``: the source fleet's ``GoldenRegistry``, when it runs
    one. Migrating a golden *fork* away releases its pins here (the
    destination copy is self-contained — export materialized the shared
    pages into the blob); detaching a registered *owner* is refused by
    ``free_tenant`` until it is unregistered.
    """
    fp = tenant_fingerprint(fleet, t)
    if fp != blob.fingerprint:
        raise MigrationError(
            f"tenant {t} changed after export (mid-migration write or "
            "maintenance op): re-export before detaching"
        )
    return fleet_lib.free_tenant(fleet, t, store=store, registry=registry)


# -- verification & orchestration --------------------------------------------


def materialize_tenant(fleet, t: int, *, store=None,
                       method: str = "auto") -> np.ndarray:
    """Tenant ``t``'s full guest-visible disk, ``(n_pages, page_size)``
    numpy, cold pages served from the host tier."""
    spec = fleet.spec
    grid = np.broadcast_to(np.arange(spec.n_pages, dtype=np.int32),
                           (spec.n_tenants, spec.n_pages))
    data, _ = fleet_lib.read_tiered(fleet, store, grid, method=method)
    return data[t]


def migrate_tenant(src_fleet, src_t: int, dst_fleet, dst_t: int, *,
                   src_store=None, dst_store=None, method: str = "auto",
                   verify: bool = True, src_registry=None):
    """Full migration round-trip: export from ``src_fleet[src_t]``,
    import into ``dst_fleet[dst_t]``, bit-verify every guest page, and
    only then detach the source.

    Returns ``(src_fleet, dst_fleet, report)``; ``report`` records the
    blob shape and whether verification ran. On any failure (stale
    export, geometry mismatch, verification miss) the source tenant is
    left fully intact.
    """
    blob = export_tenant(src_fleet, src_t, store=src_store)
    dst_fleet = import_tenant(dst_fleet, dst_t, blob, store=dst_store)
    if verify:
        want = materialize_tenant(src_fleet, src_t, store=src_store,
                                  method=method)
        got = materialize_tenant(dst_fleet, dst_t, store=dst_store,
                                 method=method)
        if want.shape != got.shape or not (
            np.asarray(want).view(np.uint8) == np.asarray(got).view(np.uint8)
        ).all():
            raise MigrationError(
                f"destination tenant {dst_t} is not bit-identical to "
                f"source tenant {src_t}; source left intact"
            )
    src_fleet = detach_tenant(src_fleet, src_t, blob, store=src_store,
                              registry=src_registry)
    report = dict(
        length=blob.length,
        rows_hot=blob.n_hot,
        rows_cold=blob.n_cold,
        blob_bytes=blob.nbytes(),
        verified=bool(verify),
    )
    return src_fleet, dst_fleet, report


# -- disk container (checkpoint-plane idiom) ---------------------------------

_META_FIELDS = ("n_pages", "page_size", "l2_per_table", "length")


def save_blob(blob: TenantBlob, path) -> None:
    """Write a blob as one compressed ``.npz`` (numpy arrays only, no
    pickle — the same container discipline as ``checkpoint/``)."""
    np.savez_compressed(
        path,
        meta=np.asarray([getattr(blob, f) for f in _META_FIELDS], np.int64),
        scalable=np.asarray(blob.scalable),
        dtype=np.frombuffer(blob.dtype.encode(), np.uint8),
        fingerprint=np.frombuffer(blob.fingerprint.encode(), np.uint8),
        l1=blob.l1,
        l2=blob.l2,
        hot_pages=blob.hot_pages,
        cold_pages=blob.cold_pages,
    )


def load_blob(path) -> TenantBlob:
    with np.load(path) as z:
        meta = {f: int(v) for f, v in zip(_META_FIELDS, z["meta"])}
        return TenantBlob(
            **meta,
            scalable=bool(z["scalable"]),
            dtype=z["dtype"].tobytes().decode(),
            fingerprint=z["fingerprint"].tobytes().decode(),
            l1=z["l1"],
            l2=z["l2"],
            hot_pages=z["hot_pages"],
            cold_pages=z["cold_pages"],
        )
