"""MaintenanceScheduler: budgeted background streaming beside serving.

The paper's §6.4 measures a ~100x guest-latency hit while a chain is
being streamed: the maintenance job competes with the guest for the data
path. Fleet-side, the equivalent anti-pattern is stop-the-world
maintenance — stream every tenant at once and eat one enormous tick.

The scheduler is the provider's background job queue instead.

**Tick budgeting.** Each ``tick()`` (driven by the serving loop between
decode steps, see ``serve/engine.py``) streams at most
``max_tenants_per_tick`` tenants, picked by occupancy — longest chains
first (they pay the worst Eq. 1 walk cost and pin the most superseded
rows), heaviest row footprint as the tie-break; chains shorter than
``stream_chain_threshold`` are left alone unless they are under
``overflow``/``snap_dropped`` pressure. The budget is what converts one
enormous stop-the-world pause into many small slices: the worst-case
tick cost is bounded by the budget, not the backlog
(``benchmarks/maintenance.py`` measures the amortization). Streaming
returns freed quanta to the fleet allocator's free list
(``fleet.stream_tenants``), and tenants that stay wedged (``overflow``
after streaming reclaimed nothing) trigger a targeted ``compact``.

**Priority aging (starvation guard).** Ranking by occupancy alone can
starve: a modest chain is outranked forever while heavier tenants keep
regrowing (write + snapshot between ticks). Every tick a tenant is a
candidate but not picked, its *age* grows, and age is added to its chain
length in the ranking (``aging_weight`` per tick of waiting, reset on
pick) — so any persistent candidate eventually outranks the churners and
gets its slice. ``aging_weight=0`` restores pure occupancy order.

**No-progress parking.** A tick that touches a tenant without changing
its occupancy fingerprint (chain length, rows held, quanta held, rows
demoted) parks that tenant: it is skipped by future ticks until
something about it changes (a write, a snapshot, a reclamation
elsewhere). Without parking, a length-2 chain (streaming shortens
nothing) or a latched overflow with nothing reclaimable would be
re-picked and futilely re-streamed every tick, and ``drain()`` would
never observe an empty backlog. Parking is what makes the queue
converge; progress anywhere un-parks automatically because the
fingerprint no longer matches.

**Demotion policy (tiering).** With a ``TieredStore`` and a
``device_page_budget``, each tick also checks the fleet's device-row
footprint against the budget and, while over it, demotes immutable
snapshot-layer pages to the host tier (``fleet.demote_tenants``) —
coldest layer first within a tenant, longest-chain tenants first across
the fleet (deep chains pin the most frozen state), and at most
``demote_rows_per_tick`` rows per tick so the transfer cost is paid in
budgeted slices like everything else here. The active COW layer is never
demoted (enforced by ``demote_tenants`` itself). Tenants whose demotion
attempt moves nothing are parked on their fingerprint like wedged
streams. See ``docs/memory.md``.
"""

from __future__ import annotations

import numpy as np

from repro.core import fleet as fleet_lib
from repro.core.fleet import ChainFleet


class MaintenanceScheduler:
    """Budgeted queue of per-tenant streaming jobs over a ``ChainFleet``.

    The scheduler owns the fleet value between ticks (functional updates:
    ``self.fleet`` is replaced, never mutated in place). The serving path
    keeps reading/writing the same object through the scheduler::

        sched = MaintenanceScheduler(fl, max_tenants_per_tick=2)
        sched.fleet = fleet.write(sched.fleet, ids, data)   # serve
        sched.tick()                                        # maintain

    ``stream_chain_threshold``: chains shorter than this are left alone
    (streaming a length-2 chain buys little and costs a repack).
    ``compact_on_overflow``: run a fleet-wide GC when streaming alone did
    not clear a tenant's ``overflow``.
    ``aging_weight``: chain-length-equivalents of priority a passed-over
    candidate gains per tick (the starvation guard); 0 disables aging.
    ``store`` + ``device_page_budget``: enable the tiering demotion
    policy — while the fleet holds more device rows than the budget,
    ticks demote immutable-layer pages into the ``TieredStore``, at most
    ``demote_rows_per_tick`` rows per tick.
    ``registry``: the fleet's ``GoldenRegistry``, when it runs one.
    Registered golden owners are content-frozen, so every maintenance
    path here leaves them alone — they are dropped from the stream and
    demotion queues, and the registry rides along into
    ``stream_tenants``/``compact``/``demote_tenants`` so fork-pinned
    rows are never relocated or spilled (the demote/fork race guard).
    """

    def __init__(self, fleet: ChainFleet, *, max_tenants_per_tick: int = 1,
                 stream_chain_threshold: int = 3,
                 compact_on_overflow: bool = True,
                 aging_weight: int = 1,
                 store=None, device_page_budget: int | None = None,
                 demote_rows_per_tick: int = 64, registry=None):
        if max_tenants_per_tick < 1:
            raise ValueError("max_tenants_per_tick must be >= 1")
        if aging_weight < 0:
            raise ValueError("aging_weight must be >= 0")
        if stream_chain_threshold < 2:
            raise ValueError(
                "stream_chain_threshold must be >= 2 (a length-1 chain "
                "has nothing below its active volume to merge)"
            )
        if device_page_budget is not None and store is None:
            raise ValueError(
                "device_page_budget needs a TieredStore to demote into"
            )
        if demote_rows_per_tick < 1:
            raise ValueError("demote_rows_per_tick must be >= 1")
        self.fleet = fleet
        self.max_tenants_per_tick = max_tenants_per_tick
        self.stream_chain_threshold = stream_chain_threshold
        self.compact_on_overflow = compact_on_overflow
        self.aging_weight = aging_weight
        self.store = store
        self.device_page_budget = device_page_budget
        self.demote_rows_per_tick = demote_rows_per_tick
        self.registry = registry
        self.rows_demoted = 0
        # tenants whose demotion attempt moved nothing, parked at their
        # fingerprint (same convergence mechanism as _wedged)
        self._demote_parked: dict[int, tuple] = {}
        # ticks spent as an unpicked candidate, per tenant: the priority
        # boost that guarantees no candidate starves behind heavier
        # tenants that keep regrowing. Reset when the tenant is picked.
        self._age: dict[int, int] = {}
        self.ticks = 0
        self.tenants_streamed = 0
        self.compactions = 0
        self.quanta_reclaimed = 0
        # tenants a tick could not help, keyed by the occupancy
        # fingerprint they were parked at: they are skipped until their
        # state changes. This is what makes the queue converge — without
        # it a length-2 chain (streaming shortens nothing) or a latched
        # overflow with nothing reclaimable would be re-picked and
        # futilely streamed/compacted on every tick, and drain() would
        # never see an empty backlog.
        self._wedged: dict[int, tuple] = {}

    def _fingerprints(self, st) -> dict[int, tuple]:
        return {
            t: (int(st["length"][t]), int(st["alloc_count"][t]),
                int(st["lease_count"][t]), int(st["cold_count"][t]))
            for t in range(self.fleet.spec.n_tenants)
        }

    def _still_wedged(self, st) -> set[int]:
        """Drop wedged tenants whose occupancy changed; return the rest."""
        fp = self._fingerprints(st)
        self._wedged = {t: f for t, f in self._wedged.items() if fp[t] == f}
        return set(self._wedged)

    # -- queue policy --------------------------------------------------------

    def _free_quanta(self, st) -> int:
        # leases are disjoint (property-tested), so free = total - held
        return self.fleet.spec.n_quanta - int(np.sum(st["lease_count"]))

    def candidates(self, st=None) -> list[int]:
        """Tenants needing streaming, most urgent first.

        Ranking: longest chain first (worst vanilla walk cost, most
        superseded rows), then largest row footprint — with each
        candidate's *age* (ticks spent waiting unpicked, times
        ``aging_weight``) added to its chain length, so a modest tenant
        cannot starve behind heavier ones that keep regrowing. Tenants
        under pressure (``overflow``/``snap_dropped``) qualify regardless
        of the length threshold — they are the ones
        ``check_pool_capacity`` would raise for. Tenants a previous tick
        could not help are parked until their occupancy changes (see
        ``_wedged``).

        Pass ``st`` (a ``fleet.tenant_stats`` result) to reuse stats the
        caller already synced off the device.
        """
        st = fleet_lib.tenant_stats(self.fleet) if st is None else st
        wedged = self._still_wedged(st)
        streamable = st["length"] >= 2          # something below the active
        need = streamable & (
            (st["length"] >= self.stream_chain_threshold)
            | st["overflow"] | st["snap_dropped"]
        )
        # tenants holding demoted pages can't stream (the merge would
        # strand their host rows) — promotion un-parks them naturally
        need &= st["cold_count"] == 0
        if self.registry is not None:
            # golden owners are content-frozen while registered: a merge
            # would rewrite the base every live fork resolves through
            need &= ~self.registry.golden_owner_mask(len(need))
        age = np.asarray([self._age.get(t, 0)
                          for t in range(len(need))], np.int64)
        rank = st["length"].astype(np.int64) + self.aging_weight * age
        order = np.lexsort((-st["alloc_count"], -rank))
        return [int(t) for t in order if need[t] and int(t) not in wedged]

    def _compactable(self, st) -> list[int]:
        """Unparked overflowed tenants — work for the compact fallback
        even when they are too short to stream (length 1)."""
        if not self.compact_on_overflow:
            return []
        self._still_wedged(st)
        return [int(t) for t in np.flatnonzero(st["overflow"])
                if int(t) not in self._wedged]

    # -- tiering demotion policy ---------------------------------------------

    def _over_budget(self, st) -> int:
        """Device rows above the HBM page budget (0 when policy is off)."""
        if self.store is None or self.device_page_budget is None:
            return 0
        return max(int(np.sum(st["alloc_count"])) - self.device_page_budget, 0)

    def _demote_candidates(self, st) -> list[int]:
        """Tenants with demotable frozen state, coldest (longest chain)
        first; parked no-progress tenants are skipped until they change."""
        fp = self._fingerprints(st)
        self._demote_parked = {t: f for t, f in self._demote_parked.items()
                               if fp[t] == f}
        need = (st["length"] >= 2) & (st["alloc_count"] > 0)
        if self.registry is not None:
            # the demote/fork race guard, queue side: a registered golden
            # base never spills (its frozen layers are exactly the
            # "immutable state below the active volume" this policy
            # targets) — and fork-pinned rows are additionally excluded
            # row-by-row inside demote_tenants
            need &= ~self.registry.golden_owner_mask(len(need))
        order = np.lexsort((-st["alloc_count"], -st["length"]))
        return [int(t) for t in order
                if need[t] and int(t) not in self._demote_parked]

    def _demote_tick(self, st) -> int:
        """One budgeted demotion slice: spill up to
        ``demote_rows_per_tick`` rows across the candidates in a single
        batched ``fleet.demote_tenants`` call (coldest layers first
        within each tenant; one L2 sync + one repack per tick)."""
        remaining = min(self.demote_rows_per_tick, self._over_budget(st))
        if remaining <= 0:
            return 0
        fp = self._fingerprints(st)
        cands = self._demote_candidates(st)
        if not cands:
            return 0
        self.fleet, rep = fleet_lib.demote_tenants(
            self.fleet, self.store, cands, max_rows=remaining,
            registry=self.registry,
        )
        done = rep["rows_demoted"]
        if done < remaining:
            # the budget was not exhausted, so every candidate the call
            # left untouched has nothing below its active layer to
            # spill: park it at its fingerprint so the policy converges
            # instead of re-scanning it every tick. (When the budget IS
            # exhausted, untouched candidates may simply not have been
            # reached — parking them would strand their frozen rows.)
            moved = set(rep["tenants"])
            for t in cands:
                if t not in moved:
                    self._demote_parked[t] = fp[t]
        self.rows_demoted += done
        return done

    def backlog(self, st=None) -> int:
        """Outstanding maintenance work: stream candidates, tenants only
        the compact fallback can help, plus tenants the demotion policy
        still needs to spill while over the device budget."""
        st = fleet_lib.tenant_stats(self.fleet) if st is None else st
        work = set(self.candidates(st)) | set(self._compactable(st))
        if self._over_budget(st) > 0:
            work |= set(self._demote_candidates(st))
        return len(work)

    # -- one tick of background work -----------------------------------------

    def tick(self) -> dict:
        """Run one maintenance slice: demote a budgeted row batch if over
        the device page budget, stream at most K tenants, compact the
        ones wedged on overflow. Returns a report of the work done.
        A drained (or fully parked) queue ticks for free: one
        tenant_stats sync, no streaming, no repack, no transfers."""
        st0 = fleet_lib.tenant_stats(self.fleet)
        cands = self.candidates(st0)
        picks = cands[: self.max_tenants_per_tick]
        compactable = self._compactable(st0)
        need_demote = (self._over_budget(st0) > 0
                       and bool(self._demote_candidates(st0)))
        self.ticks += 1
        # starvation guard: passed-over candidates gain priority, picked
        # ones reset — any persistent candidate is eventually served. A
        # tenant that stopped qualifying (pressure relieved elsewhere,
        # e.g. by the compact path) drops its accumulated age: a stale
        # boost must not let it jump the queue when it next qualifies.
        cand_set = set(cands)
        self._age = {t: a for t, a in self._age.items() if t in cand_set}
        for t in cands[self.max_tenants_per_tick:]:
            self._age[t] = self._age.get(t, 0) + 1
        for t in picks:
            self._age.pop(t, None)
        if not picks and not compactable and not need_demote:
            return dict(streamed=[], compacted=False, quanta_reclaimed=0,
                        rows_demoted=0, backlog=0)

        fp_before = self._fingerprints(st0)
        free_before = self._free_quanta(st0)
        n_t = self.fleet.spec.n_tenants
        # spill first: demotion frees device rows through the same
        # _reclaim repack streaming uses, so a single tick's transfers
        # stay bounded by demote_rows_per_tick + the stream budget
        demoted = self._demote_tick(st0) if need_demote else 0
        if picks:
            mask = np.zeros(n_t, bool)
            mask[picks] = True
            # merge everything below each tenant's active volume
            upto = st0["length"] - 2
            self.fleet = fleet_lib.stream_tenants(self.fleet, mask, upto,
                                                  registry=self.registry)
        compacted = False
        still_over = np.flatnonzero(np.asarray(self.fleet.overflow))
        need_compact = [int(t) for t in still_over
                        if int(t) not in self._wedged]
        if self.compact_on_overflow and need_compact:
            # compact only the tenants that need it — a fleet-wide repack
            # inside one serving tick would be the stop-the-world cliff
            # this scheduler exists to avoid
            mask = np.zeros(n_t, bool)
            mask[need_compact] = True
            self.fleet = fleet_lib.compact(self.fleet, mask,
                                           registry=self.registry)
            compacted = True
        # park every touched tenant that made no progress (no-op stream,
        # unreclaimable overflow, ...) at its current occupancy, so it is
        # not re-picked until something about it changes
        st1 = fleet_lib.tenant_stats(self.fleet)
        fp_after = self._fingerprints(st1)
        for t in set(picks) | set(compactable):
            if fp_after[t] == fp_before[t]:
                self._wedged[t] = fp_after[t]
        reclaimed = self._free_quanta(st1) - free_before
        self.tenants_streamed += len(picks)
        self.compactions += int(compacted)
        self.quanta_reclaimed += max(reclaimed, 0)
        return dict(
            streamed=picks,
            compacted=compacted,
            quanta_reclaimed=reclaimed,
            rows_demoted=demoted,
            backlog=self.backlog(st1),
        )

    def drain(self, *, max_ticks: int = 10_000) -> int:
        """Tick until the queue is empty (tests / shutdown). Returns the
        number of ticks it took."""
        for i in range(max_ticks):
            if not self.backlog():
                return i
            self.tick()
        raise RuntimeError("maintenance backlog did not drain")

    def stats(self) -> dict:
        """Lifetime counters plus the fleet's current occupancy."""
        out = dict(
            ticks=self.ticks,
            tenants_streamed=self.tenants_streamed,
            compactions=self.compactions,
            quanta_reclaimed=self.quanta_reclaimed,
            rows_demoted=self.rows_demoted,
            max_wait=max(self._age.values(), default=0),
            **fleet_lib.fleet_stats(self.fleet),
        )
        if self.store is not None:
            out.update(self.store.stats())
        return out
