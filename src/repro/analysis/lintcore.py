"""fleetlint core: findings, config, disable comments, the runner."""

from __future__ import annotations

import re
from dataclasses import dataclass
from pathlib import Path

_DISABLE_RE = re.compile(r"#\s*fleetlint:\s*disable(?:=([A-Za-z0-9_,\s]+))?")


@dataclass(frozen=True)
class Finding:
    code: str      # FL001..FL005
    relpath: str   # posix path relative to the scan root
    line: int
    col: int
    message: str
    hint: str


@dataclass
class LintConfig:
    """Repo-specific knobs; the defaults match this tree and are also
    suffix-based so fixture trees in tmp dirs lint identically."""

    # FL001: modules allowed to spell the raw entry-format bits
    fl001_exempt: tuple[str, ...] = ("core/format.py",)
    # FL002: hot-path roots (qualnames) and designed traversal boundaries
    fl002_roots: tuple[str, ...] = ("Engine.step", "PagedKVCache.prepare_step",
                                    "PagedKVCache.prepare_step_fused")
    # MaintenanceScheduler.tick is the *deliberately* host-side
    # maintenance plane (docs/memory.md): it runs between decode steps,
    # not inside them, so the traversal stops there.
    fl002_boundaries: frozenset[str] = frozenset({"MaintenanceScheduler.tick"})
    # attribute names that hold device-resident arrays
    fl002_device_attrs: frozenset[str] = frozenset(
        {"pool", "pool_k", "pool_v", "l1", "l2"})
    # FL004: modules that own pool/free-list/lease state
    fl004_owner_modules: tuple[str, ...] = (
        "core/fleet.py", "core/chain.py", "core/store.py", "core/golden.py",
        "kvcache/paged.py")
    fl004_protected_attrs: frozenset[str] = frozenset(
        {"pool", "pool_k", "pool_v", "l1", "l2", "_free", "_free_tenants",
         "_data", "lease_owner", "lease_index", "lease_count"})


def disabled_codes_at(lines: list[str], lineno: int) -> set[str]:
    """Codes disabled by a ``# fleetlint: disable[=CODES]`` comment on
    the given 1-based line ('*' means all)."""
    if not (1 <= lineno <= len(lines)):
        return set()
    m = _DISABLE_RE.search(lines[lineno - 1])
    if not m:
        return set()
    if m.group(1) is None:
        return {"*"}
    return {c.strip().upper() for c in m.group(1).split(",") if c.strip()}


def _suppressed(f: Finding, lines: list[str]) -> bool:
    for ln in (f.line, f.line - 1):
        codes = disabled_codes_at(lines, ln)
        if "*" in codes or f.code in codes:
            return True
    return False


def run_lint(root: Path, config: LintConfig | None = None) -> list[Finding]:
    """Lint every ``*.py`` under *root*; returns unsuppressed findings,
    sorted by (path, line, code). Unparseable files surface as FL000."""
    from repro.analysis.callgraph import PackageIndex
    from repro.analysis.rules import ALL_RULES

    cfg = config or LintConfig()
    index = PackageIndex(Path(root))
    findings: list[Finding] = []
    for rel, msg in index.errors:
        findings.append(Finding("FL000", rel, 1, 0,
                                f"could not parse: {msg}", "fix the syntax"))
    for rule in ALL_RULES:
        findings.extend(rule(index, cfg))

    lines_by_rel = {m.relpath: m.lines for m in index.modules}
    kept = [f for f in findings
            if not _suppressed(f, lines_by_rel.get(f.relpath, []))]
    return sorted(kept, key=lambda f: (f.relpath, f.line, f.col, f.code))


def render(findings: list[Finding]) -> str:
    out = []
    for f in findings:
        out.append(f"{f.relpath}:{f.line}:{f.col + 1}: {f.code} {f.message}")
        out.append(f"    fix: {f.hint}")
    return "\n".join(out)
