"""fleetlint: AST-level invariant checks for the fleet's contracts.

The rules encode the invariants that keep the reproduction honest
(see ``docs/invariants.md``):

* **FL001** — bit-format literals belong in ``core/format.py`` only;
* **FL002** — the decode hot path (``Engine.step`` /
  ``PagedKVCache.prepare_step`` and everything reachable from them)
  performs no device->host sync beyond the designed boundaries;
* **FL003** — jitted / Pallas-wrapped functions carry no retrace
  hazards (mutable closures, shape-branching on traced args);
* **FL004** — pool / free-list / L2 state is written only by its
  owners (``ChainFleet``, ``Chain``, ``TieredStore``, ``PagedKVCache``);
* **FL005** — Pallas kernel bodies and ``index_map`` s are pure.

Everything here is stdlib-only (``ast`` + ``pathlib``): the linter must
run in CI's lint job, where jax is not installed.
"""

from repro.analysis.lintcore import Finding, LintConfig, render, run_lint
from repro.analysis.rules import RULES

__all__ = ["Finding", "LintConfig", "RULES", "render", "run_lint"]
