"""The fleetlint rules (FL001-FL005).

Each rule is a function ``(index, config) -> list[Finding]``; the
runner in ``lintcore`` applies disable-comment suppression afterwards.
"""

from __future__ import annotations

import ast

from repro.analysis.callgraph import (
    FunctionInfo, PackageIndex, dotted, param_names,
)
from repro.analysis.lintcore import Finding, LintConfig

RULES: dict[str, str] = {
    "FL001": "bit-format literal outside core/format.py",
    "FL002": "device->host sync inside the decode hot path",
    "FL003": "retrace hazard in a jitted/Pallas function",
    "FL004": "pool/free-list/L2 write outside its owner",
    "FL005": "impure Pallas kernel body or index_map",
}

# L2 entry-format values (core/format.py is their single home).
# PTR_MASK and the word0 flag bits are distinctive enough to flag as
# bare literals; BFI_MASK/FLAG_BFI_VALID (65535/65536) collide with
# innocent sizes (vocab_size=65536), so those only count in bitwise
# expressions.
_HARD_VALUES = {268435455, 268435456, 536870912, 1073741824, 2147483648}  # fleetlint: disable=FL001
_BITWISE_ONLY_VALUES = {65535, 65536}  # fleetlint: disable=FL001
_ENTRY_SHIFTS = {28, 29, 30, 31}
_BITWISE_OPS = (ast.BitAnd, ast.BitOr, ast.BitXor, ast.LShift, ast.RShift)

_MUTATORS = {"append", "extend", "insert", "pop", "remove", "clear",
             "add", "discard", "update", "setdefault", "fill", "sort",
             "popitem"}

_PURE_BUILTINS = {"min", "max", "abs", "divmod", "len", "int", "sum", "tuple"}


def _finding(code: str, mod_rel: str, node: ast.AST, message: str,
             hint: str) -> Finding:
    return Finding(code=code, relpath=mod_rel, line=node.lineno,
                   col=node.col_offset, message=message, hint=hint)


# ---------------------------------------------------------------- FL001

def rule_fl001(index: PackageIndex, cfg: LintConfig) -> list[Finding]:
    hint = ("route the bits through the named constants in core/format.py "
            "(fmt.PTR_MASK, fmt.FLAG_*, fmt.BFI_MASK)")
    out = []
    for mod in index.modules:
        if any(mod.relpath.endswith(s) for s in cfg.fl001_exempt):
            continue

        def walk(node: ast.AST, in_bitwise: bool) -> None:
            here = in_bitwise
            if isinstance(node, ast.BinOp) and isinstance(node.op, _BITWISE_OPS):
                here = True
                if (isinstance(node.op, ast.LShift)
                        and isinstance(node.right, ast.Constant)
                        and isinstance(node.right.value, int)):
                    n = node.right.value
                    left_is_one = (isinstance(node.left, ast.Constant)
                                   and node.left.value == 1)
                    if n in _ENTRY_SHIFTS or (n == 16 and left_is_one):
                        out.append(_finding(
                            "FL001", mod.relpath, node,
                            f"shift by {n} re-derives an L2 entry-format "
                            "bit position", hint))
            elif isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Invert):
                here = True
            if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                    and not isinstance(node.value, bool):
                v = node.value
                if v in _HARD_VALUES or (here and v in _BITWISE_ONLY_VALUES):
                    out.append(_finding(
                        "FL001", mod.relpath, node,
                        f"integer literal {v} duplicates an L2 entry-format "
                        "constant", hint))
            for child in ast.iter_child_nodes(node):
                walk(child, here)

        walk(mod.tree, False)
    return _dedup(out)


def _dedup(findings: list[Finding]) -> list[Finding]:
    seen, out = set(), []
    for f in findings:
        k = (f.code, f.relpath, f.line, f.col)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


# ---------------------------------------------------------------- FL002

class _TaintScan:
    """Statement-order taint tracking inside one function.

    Sources: jnp./jax. expressions, calls to known-jitted package
    functions, reads of device-resident attributes (pool, l1, l2, ...).
    Sinks: int()/float()/bool(), any np.* call, and .item() applied to a
    tainted value — each sink is a host sync; its *result* is host-side
    (untainted), so downstream use of an already-synced value is clean.
    """

    def __init__(self, fn: FunctionInfo, index: PackageIndex,
                 cfg: LintConfig, root: str, out: list[Finding]):
        self.fn = fn
        self.index = index
        self.cfg = cfg
        self.root = root
        self.out = out
        self.tainted: set[str] = set()

    def run(self) -> None:
        self.stmts(self.fn.node.body)

    # -- statements -----------------------------------------------------

    def stmts(self, body) -> None:
        for s in body:
            self.stmt(s)

    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            t = self.expr(s.value)
            for target in s.targets:
                self.bind(target, t)
        elif isinstance(s, ast.AugAssign):
            t = self.expr(s.value) or self.expr(s.target)
            self.bind(s.target, t)
        elif isinstance(s, ast.AnnAssign) and s.value is not None:
            self.bind(s.target, self.expr(s.value))
        elif isinstance(s, (ast.Expr, ast.Return)):
            if getattr(s, "value", None) is not None:
                self.expr(s.value)
        elif isinstance(s, ast.For):
            self.bind(s.target, self.expr(s.iter))
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.While):
            self.expr(s.test)
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.If):
            self.expr(s.test)
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.With):
            for item in s.items:
                t = self.expr(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, t)
            self.stmts(s.body)
        elif isinstance(s, ast.Try):
            self.stmts(s.body)
            for h in s.handlers:
                self.stmts(h.body)
            self.stmts(s.orelse)
            self.stmts(s.finalbody)
        elif isinstance(s, (ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.expr):
                    self.expr(child)
        # nested defs/classes are scanned on their own if reachable

    def bind(self, target: ast.expr, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self.bind(el, tainted)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, tainted)
        # attribute/subscript targets hold no local taint state

    # -- expressions ----------------------------------------------------

    def expr(self, e: ast.expr) -> bool:
        """True iff *e* evaluates to a (possible) device value."""
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            self.expr(e.value)
            return e.attr in self.cfg.fl002_device_attrs
        if isinstance(e, ast.Call):
            return self.call(e)
        if isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Lambda):
            return False
        tainted = False
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                tainted |= self.expr(child)
        return tainted

    def call(self, e: ast.Call) -> bool:
        args_tainted = False
        for a in e.args:
            v = a.value if isinstance(a, ast.Starred) else a
            args_tainted |= self.expr(v)
        for kw in e.keywords:
            args_tainted |= self.expr(kw.value)

        f = dotted(e.func)
        base = f.split(".")[0] if f else None
        name = f.split(".")[-1] if f else None

        # .item() on a tainted value: unconditional sync
        if isinstance(e.func, ast.Attribute) and e.func.attr == "item":
            if self.expr(e.func.value):
                self.sink(e, ".item()")
            return False

        if base in ("jnp", "jax"):
            return True  # device-producing expression

        if isinstance(e.func, ast.Name) and e.func.id in ("int", "float", "bool"):
            if args_tainted:
                self.sink(e, f"{e.func.id}(...)")
            return False

        if base in ("np", "numpy"):
            if args_tainted:
                self.sink(e, f"{f}(...)")
            return False  # numpy results live on the host

        if name in self.index.jitted_names:
            return True  # call into a jitted package function

        if isinstance(e.func, ast.Attribute):
            self.expr(e.func.value)

        # unknown helper: conservatively propagate argument taint
        return args_tainted

    def sink(self, node: ast.AST, what: str) -> None:
        self.out.append(_finding(
            "FL002", self.fn.module.relpath, node,
            f"{what} forces a device->host sync inside the decode hot path "
            f"({self.fn.qualname}, reachable from {self.root})",
            "hoist the sync out of the per-step path, or waive the designed "
            "boundary with `# fleetlint: disable=FL002` and a justification"))


def rule_fl002(index: PackageIndex, cfg: LintConfig) -> list[Finding]:
    out: list[Finding] = []
    for root in cfg.fl002_roots:
        roots = index.by_qualname.get(root, [])
        seen: set[int] = set()
        queue = list(roots)
        while queue:
            fn = queue.pop()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            if fn.qualname in cfg.fl002_boundaries:
                continue
            if _def_line_disables(fn, "FL002"):
                continue  # an explicitly waived function is a boundary
            _TaintScan(fn, index, cfg, root, out).run()
            for callee in fn.callees:
                queue.extend(index.resolve(callee))
    return _dedup(out)


def _def_line_disables(fn: FunctionInfo, code: str) -> bool:
    from repro.analysis.lintcore import disabled_codes_at
    lines = fn.module.lines
    for ln in (fn.node.lineno, fn.node.lineno - 1):
        codes = disabled_codes_at(lines, ln)
        if "*" in codes or code in codes:
            return True
    return False


# ---------------------------------------------------------------- FL003

def rule_fl003(index: PackageIndex, cfg: LintConfig) -> list[Finding]:
    out = []
    for mod in index.modules:
        for fn in mod.functions:
            if not (fn.is_jitted or fn.is_kernel):
                continue
            params = param_names(fn.node)
            local: set[str] = set(params)
            for sub in ast.walk(fn.node):
                if isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    targets = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                local.add(n.id)
            for sub in ast.walk(fn.node):
                if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                        and sub.id in mod.mutable_globals \
                        and sub.id not in local:
                    out.append(_finding(
                        "FL003", mod.relpath, sub,
                        f"jitted function {fn.qualname} closes over mutable "
                        f"module state '{sub.id}' (defined at line "
                        f"{mod.mutable_globals[sub.id]}): jit captures it at "
                        "trace time and never sees later mutation",
                        "pass the value as an argument (hashable/static) or "
                        "freeze it into an immutable constant"))
                if isinstance(sub, (ast.If, ast.While)):
                    for n in ast.walk(sub.test):
                        if (isinstance(n, ast.Attribute) and n.attr == "shape"
                                and isinstance(n.value, ast.Name)
                                and n.value.id in params):
                            out.append(_finding(
                                "FL003", mod.relpath, sub,
                                f"jitted function {fn.qualname} branches on "
                                f"`{n.value.id}.shape`: every new shape "
                                "retraces and the branches compile to "
                                "different programs",
                                "lift the shape decision to the (static) "
                                "call site, or mark the argument static"))
    return _dedup(out)


# ---------------------------------------------------------------- FL004

def rule_fl004(index: PackageIndex, cfg: LintConfig) -> list[Finding]:
    hint = ("route the mutation through the owning class "
            "(ChainFleet / Chain / TieredStore / PagedKVCache method) so "
            "lease bookkeeping stays consistent")
    out = []
    for mod in index.modules:
        if any(mod.relpath.endswith(s) for s in cfg.fl004_owner_modules):
            continue

        def protected(t: ast.expr) -> str | None:
            if isinstance(t, ast.Attribute) and t.attr in cfg.fl004_protected_attrs:
                return t.attr
            if isinstance(t, ast.Subscript):
                return protected(t.value)
            return None

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                flat = []
                for t in targets:
                    flat.extend(t.elts if isinstance(t, (ast.Tuple, ast.List))
                                else [t])
                for t in flat:
                    attr = protected(t)
                    if attr:
                        out.append(_finding(
                            "FL004", mod.relpath, node,
                            f"write to protected state '.{attr}' outside its "
                            "owner module", hint))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATORS:
                attr = protected(node.func.value)
                if attr:
                    out.append(_finding(
                        "FL004", mod.relpath, node,
                        f"mutating call .{node.func.attr}() on protected "
                        f"state '.{attr}' outside its owner module", hint))
    return _dedup(out)


# ---------------------------------------------------------------- FL005

def rule_fl005(index: PackageIndex, cfg: LintConfig) -> list[Finding]:
    out = []
    for mod in index.modules:
        for fn in mod.functions:
            if fn.is_kernel:
                _scan_kernel_body(fn, out)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                f = dotted(node.func)
                if f is not None and f.split(".")[-1] == "BlockSpec":
                    for lam in _index_map_lambdas(node):
                        _scan_index_map(lam, mod, out)
    return _dedup(out)


def _scan_kernel_body(fn: FunctionInfo, out: list[Finding]) -> None:
    params = param_names(fn.node)
    hint = ("a Pallas kernel body must be pure: all outputs go through "
            "Ref parameters; move the side effect to the host wrapper")
    for sub in ast.walk(fn.node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "print":
            out.append(_finding(
                "FL005", fn.module.relpath, sub,
                f"print() inside Pallas kernel {fn.qualname}", hint))
        elif isinstance(sub, (ast.Global, ast.Nonlocal)):
            out.append(_finding(
                "FL005", fn.module.relpath, sub,
                f"global/nonlocal inside Pallas kernel {fn.qualname}", hint))
        elif isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute) \
                and sub.func.attr in _MUTATORS \
                and not _is_at_indexer(sub.func.value):
            out.append(_finding(
                "FL005", fn.module.relpath, sub,
                f"container mutation .{sub.func.attr}() inside Pallas kernel "
                f"{fn.qualname}", hint))
        elif isinstance(sub, (ast.Assign, ast.AugAssign)):
            targets = sub.targets if isinstance(sub, ast.Assign) \
                else [sub.target]
            for t in targets:
                if isinstance(t, ast.Subscript):
                    base = t.value
                    while isinstance(base, ast.Subscript):
                        base = base.value
                    if not (isinstance(base, ast.Name) and base.id in params):
                        out.append(_finding(
                            "FL005", fn.module.relpath, sub,
                            "subscript write to a non-parameter object "
                            f"inside Pallas kernel {fn.qualname}", hint))


def _is_at_indexer(node: ast.expr) -> bool:
    """True for ``X.at[...]`` — jnp's *functional* update, not a mutation."""
    return (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Attribute)
            and node.value.attr == "at")


def _index_map_lambdas(call: ast.Call) -> list[ast.Lambda]:
    out = []
    for kw in call.keywords:
        if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
            out.append(kw.value)
    for a in call.args:
        if isinstance(a, ast.Lambda):
            out.append(a)
    return out


def _scan_index_map(lam: ast.Lambda, mod, out: list[Finding]) -> None:
    params = {p.arg for p in (*lam.args.posonlyargs, *lam.args.args,
                              *lam.args.kwonlyargs)}
    allowed = params | _PURE_BUILTINS | mod.constants
    hint = ("an index_map must be a pure function of its grid indices "
            "(plus scalar-prefetch refs): no free variables, no impure calls")
    for sub in ast.walk(lam.body):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                and sub.id not in allowed:
            out.append(_finding(
                "FL005", mod.relpath, sub,
                f"index_map references free variable '{sub.id}'", hint))
        elif isinstance(sub, ast.Call):
            f = dotted(sub.func)
            leaf = f.split(".")[-1] if f else None
            if leaf not in _PURE_BUILTINS and leaf not in params:
                out.append(_finding(
                    "FL005", mod.relpath, sub,
                    f"index_map calls '{f or '<expr>'}'", hint))


ALL_RULES = [rule_fl001, rule_fl002, rule_fl003, rule_fl004, rule_fl005]
