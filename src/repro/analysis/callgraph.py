"""Package-wide AST index: functions, call edges, jit/kernel detection.

The index is deliberately conservative: calls are resolved by *name*
(a call to ``x.foo()`` matches every function/method named ``foo`` in
the scanned tree), which over-approximates reachability — the right
bias for a linter guarding a hot path.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

FuncNode = ast.FunctionDef | ast.AsyncFunctionDef

_MUTABLE_CTORS = {"list", "dict", "set", "defaultdict", "deque", "Counter", "OrderedDict"}


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_decorator(dec: ast.expr) -> bool:
    d = dotted(dec)
    if d is not None and (d == "jit" or d.endswith(".jit")):
        return True
    if isinstance(dec, ast.Call):
        f = dotted(dec.func)
        if f in ("partial", "functools.partial") and dec.args:
            a = dotted(dec.args[0])
            return a is not None and (a == "jit" or a.endswith(".jit"))
        if f is not None and (f == "jit" or f.endswith(".jit")):
            return True  # @jax.jit(static_argnums=...) factory form
    return False


def param_names(node: FuncNode) -> set[str]:
    a = node.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


@dataclass
class FunctionInfo:
    name: str                 # bare name
    qualname: str             # Class.name for methods, name otherwise
    node: FuncNode
    module: ModuleInfo
    is_jitted: bool = False   # @jax.jit / @partial(jax.jit, ...) / f = jit(f)
    is_kernel: bool = False   # appears as the kernel arg of a pl.pallas_call
    callees: set[str] = field(default_factory=set)  # bare names called


@dataclass
class ModuleInfo:
    path: Path
    relpath: str              # posix, relative to the scan root
    tree: ast.Module
    lines: list[str]
    functions: list[FunctionInfo] = field(default_factory=list)
    # module-level names bound to plain literals (usable in index_maps)
    constants: set[str] = field(default_factory=set)
    # module-level names bound to mutable containers (retrace hazards)
    mutable_globals: dict[str, int] = field(default_factory=dict)


class PackageIndex:
    """Parse every ``*.py`` under *root* and index functions and calls."""

    def __init__(self, root: Path):
        self.root = root
        self.modules: list[ModuleInfo] = []
        self.by_name: dict[str, list[FunctionInfo]] = {}
        self.by_qualname: dict[str, list[FunctionInfo]] = {}
        self.errors: list[tuple[str, str]] = []  # (relpath, message)
        for path in sorted(root.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            try:
                src = path.read_text()
                tree = ast.parse(src, filename=str(path))
            except (SyntaxError, UnicodeDecodeError) as e:
                self.errors.append((rel, str(e)))
                continue
            mod = ModuleInfo(path=path, relpath=rel, tree=tree,
                             lines=src.splitlines())
            self._index_module(mod)
            self.modules.append(mod)
        for mod in self.modules:
            for fn in mod.functions:
                self.by_name.setdefault(fn.name, []).append(fn)
                self.by_qualname.setdefault(fn.qualname, []).append(fn)
        self.jitted_names = {f.name for fs in self.by_name.values()
                             for f in fs if f.is_jitted}

    # -- module indexing ------------------------------------------------

    def _index_module(self, mod: ModuleInfo) -> None:
        jit_assigned: set[str] = set()   # f = jax.jit(f) at module level
        kernel_names: set[str] = set()   # first arg of pl.pallas_call

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                f = dotted(node.func)
                if f is not None and f.split(".")[-1] == "pallas_call":
                    k = self._kernel_arg(node)
                    if k:
                        kernel_names.add(k)

        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call):
                f = dotted(stmt.value.func)
                if f is not None and (f == "jit" or f.endswith(".jit")):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            jit_assigned.add(t.id)
                    if stmt.value.args:
                        a = dotted(stmt.value.args[0])
                        if a:
                            jit_assigned.add(a)
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if not isinstance(t, ast.Name):
                        continue
                    if isinstance(stmt.value, ast.Constant):
                        mod.constants.add(t.id)
                    elif self._is_mutable_ctor(stmt.value):
                        mod.mutable_globals[t.id] = stmt.lineno

        def visit(body, prefix: str) -> None:
            for stmt in body:
                if isinstance(stmt, FuncNode):
                    qual = f"{prefix}{stmt.name}" if prefix else stmt.name
                    fn = FunctionInfo(
                        name=stmt.name, qualname=qual, node=stmt, module=mod,
                        is_jitted=(any(_is_jit_decorator(d)
                                       for d in stmt.decorator_list)
                                   or stmt.name in jit_assigned),
                        is_kernel=stmt.name in kernel_names,
                    )
                    fn.callees = self._callees(stmt)
                    mod.functions.append(fn)
                    visit(stmt.body, prefix)  # nested defs keep outer prefix
                elif isinstance(stmt, ast.ClassDef):
                    visit(stmt.body, f"{stmt.name}.")

        visit(mod.tree.body, "")

    @staticmethod
    def _is_mutable_ctor(value: ast.expr) -> bool:
        if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
            return True
        if isinstance(value, ast.Call):
            f = dotted(value.func)
            return f is not None and f.split(".")[-1] in _MUTABLE_CTORS
        return False

    @staticmethod
    def _kernel_arg(call: ast.Call) -> str | None:
        """The kernel function name passed to a ``pallas_call``."""
        args = list(call.args)
        for kw in call.keywords:
            if kw.arg == "kernel":
                args.insert(0, kw.value)
        if not args:
            return None
        k = args[0]
        if isinstance(k, ast.Call):  # partial(kernel, ...)
            f = dotted(k.func)
            if f in ("partial", "functools.partial") and k.args:
                k = k.args[0]
        if isinstance(k, ast.Name):
            return k.id
        if isinstance(k, ast.Attribute):
            return k.attr
        return None

    @staticmethod
    def _callees(node: FuncNode) -> set[str]:
        out = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                if isinstance(sub.func, ast.Name):
                    out.add(sub.func.id)
                elif isinstance(sub.func, ast.Attribute):
                    out.add(sub.func.attr)
        return out

    # -- queries --------------------------------------------------------

    def resolve(self, name: str) -> list[FunctionInfo]:
        """Every function a call spelled ``name`` might reach (by name)."""
        return self.by_name.get(name, [])
