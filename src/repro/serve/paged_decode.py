"""Paged decode step for dense/MoE transformers (continuous batching).

Unlike ``transformer.decode_step`` (dense per-request cache, used by the
dry-run serve cells), this path reads K/V through *direct block tables*
from a shared paged pool — the serving integration of the paper's
direct-access principle. Per-sequence positions come from ``lengths``
(sequences in a continuous batch are at different positions).

The attention inner loop is ``kernels/paged_attention`` (Pallas on TPU,
oracle on CPU). Pool writes happen in-step at (table[len // bs], len % bs).

Block tables must be fully **device-resident**: every id in ``tables``
must address live pool data. Host-tier promotion of spilled (cold)
blocks happens strictly before this step, inside
``PagedKVCache.prepare_step`` — by the time a table reaches this jitted
function there are no cold positions left (see ``docs/memory.md``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import ops as pa_ops
from repro.kernels.paged_attention import ref as pa_ref
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.transformer import output_matrix


@partial(jax.jit, static_argnames=("cfg",))
def paged_decode_step(cfg: ModelConfig, params, pool_k, pool_v, tables,
                      lengths, tokens):
    """One decode step for B sequences.

    pool_k/pool_v: (L, nb, bs, Hkv, D); tables: (B, M) int32 (direct);
    lengths: (B,) int32 (tokens already in each sequence);
    tokens: (B, 1) int32. Returns (logits (B, V), new_pool_k, new_pool_v).
    """
    b = tokens.shape[0]
    bs = pool_k.shape[2]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]      # (B,1,d)
    positions = lengths[:, None]                             # (B,1)

    blk = jnp.take_along_axis(tables, (lengths // bs)[:, None], axis=1)[:, 0]
    off = lengths % bs

    def body(x, inputs):
        p, pk, pv = inputs
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, positions, rope_theta=cfg.rope_theta,
                             use_rope=cfg.use_rope)
        pk = pk.at[blk, off].set(k[:, 0].astype(pk.dtype))
        pv = pv.at[blk, off].set(v[:, 0].astype(pv.dtype))
        attn = pa_ops.paged_attention(
            q[:, 0].astype(L.COMPUTE_DTYPE), pk, pv, tables, lengths + 1
        )
        x = x + attn.reshape(b, 1, -1).astype(x.dtype) @ p["attn"]["wo"].astype(x.dtype)
        h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff, _ = moe_lib.moe_apply(cfg, p["ff"], h2)
        else:
            ff = L.mlp_apply(p["ff"], h2, cfg.activation)
        return x + ff, (pk, pv)

    x, (pk, pv) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ output_matrix(cfg, params).astype(x.dtype)).astype(
        jnp.float32
    )
    return logits, pk, pv


@partial(jax.jit, static_argnames=("cfg",))
def paged_suffix_prefill(cfg: ModelConfig, params, pool_k, pool_v, tables,
                         slots_blk, slots_off, attn_lens, tokens):
    """Prefill S suffix tokens of ONE sequence whose first tokens already
    sit in the paged pool — the golden-fork admission step.

    A suffix chunk is ordinary causal prefill against a paged prefix:
    per layer, every suffix position's K/V is computed from the same
    input hidden states, scattered into its COW-prepared pool slot, and
    attention then runs the suffix positions as a *batch of S queries*
    over the shared block table with per-position lengths — position i
    sees the prefix plus suffix tokens ``<= i``, exactly causal. ONE
    device dispatch replaces S per-token decode steps.

    pool_k/pool_v: (L, nb, bs, Hkv, D); tables: (S, M) int32 (the
    sequence's table broadcast per position); slots_blk/slots_off: (S,)
    int32 pool slot of each suffix position (padded positions point at a
    reserved scratch block); attn_lens: (S,) int32 — prefix + i + 1 for
    real positions (1 for padded rows, whose outputs are discarded);
    tokens: (1, S) int32. Returns (logits (S, V), new_pool_k,
    new_pool_v) — the caller reads the last *real* row.
    """
    s = tokens.shape[1]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]      # (1,S,d)
    positions = (attn_lens - 1)[None, :]                     # (1,S)

    def body(x, inputs):
        p, pk, pv = inputs
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, positions, rope_theta=cfg.rope_theta,
                             use_rope=cfg.use_rope)
        pk = pk.at[slots_blk, slots_off].set(k[0].astype(pk.dtype))
        pv = pv.at[slots_blk, slots_off].set(v[0].astype(pv.dtype))
        attn = pa_ops.paged_attention(
            q[0].astype(L.COMPUTE_DTYPE), pk, pv, tables, attn_lens
        )
        x = x + attn.reshape(1, s, -1).astype(x.dtype) @ p["attn"]["wo"].astype(x.dtype)
        h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff, _ = moe_lib.moe_apply(cfg, p["ff"], h2)
        else:
            ff = L.mlp_apply(p["ff"], h2, cfg.activation)
        return x + ff, (pk, pv)

    x, (pk, pv) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[0] @ output_matrix(cfg, params).astype(x.dtype)).astype(
        jnp.float32
    )
    return logits, pk, pv


@partial(jax.jit, static_argnames=("cfg",))
def paged_decode_step_fused(cfg: ModelConfig, params, pool_k, pool_v, l2,
                            chain_lengths, tenants, lengths, write_blocks,
                            tokens):
    """One decode step reading K/V *through the stacked fleet index*.

    The fused counterpart of ``paged_decode_step``: no block tables are
    materialized anywhere — the attention plane receives the packed L2
    word0 stacks (``l2[..., 0]``), per-tenant ``chain_lengths`` and the
    batch's ``tenants`` mapping, and resolves each KV block by walking
    the chain in-grid (``kernels/paged_attention``). The in-step K/V
    scatter lands in ``write_blocks`` — the COW-prepared slots
    ``PagedKVCache.prepare_step_fused`` stamped into the index before
    this jit, so the walk resolves the write block too.

    Backend split (hot-path policy, ``docs/kernels.md``): on TPU every
    layer runs the compiled fused kernel; elsewhere the batch's tables
    are resolved ONCE inside this jit by the pinned walk oracle and the
    table-consuming oracle serves every layer — still zero host-side
    materialization, transfer or sync.

    pool_k/pool_v: (L, nb, bs, Hkv, D); l2: (T, C, P, 2) uint32;
    chain_lengths: (T,); tenants/lengths/write_blocks: (B,) int32;
    tokens: (B, 1) int32. Returns (logits (B, V), new_pool_k, new_pool_v).
    """
    b = tokens.shape[0]
    bs = pool_k.shape[2]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]      # (B,1,d)
    positions = lengths[:, None]                             # (B,1)
    w0 = l2[..., 0]
    off = lengths % bs
    on_tpu = jax.default_backend() == "tpu"
    if not on_tpu:
        tables = pa_ref.fused_tables_ref(w0, chain_lengths, tenants)

    def body(x, inputs):
        p, pk, pv = inputs
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, positions, rope_theta=cfg.rope_theta,
                             use_rope=cfg.use_rope)
        pk = pk.at[write_blocks, off].set(k[:, 0].astype(pk.dtype))
        pv = pv.at[write_blocks, off].set(v[:, 0].astype(pv.dtype))
        qh = q[:, 0].astype(L.COMPUTE_DTYPE)
        if on_tpu:
            attn = pa_ops.fused_attention(qh, pk, pv, w0, chain_lengths,
                                          tenants, lengths + 1)
        else:
            attn = pa_ref.paged_attention_ref(qh, pk, pv, tables,
                                              lengths + 1)
        x = x + attn.reshape(b, 1, -1).astype(x.dtype) @ p["attn"]["wo"].astype(x.dtype)
        h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff, _ = moe_lib.moe_apply(cfg, p["ff"], h2)
        else:
            ff = L.mlp_apply(p["ff"], h2, cfg.activation)
        return x + ff, (pk, pv)

    x, (pk, pv) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ output_matrix(cfg, params).astype(x.dtype)).astype(
        jnp.float32
    )
    return logits, pk, pv
