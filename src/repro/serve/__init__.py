"""serve subsystem."""
