"""Serving engine: continuous batching over the fleet-backed KV cache.

Request lifecycle: ``add_request(prompt)`` prefills through the model and
streams the K/V into the paged pool (one bulk fleet write, not a
per-token loop); ``fork_request`` COW-forks a sequence (shared system
prompts / beam candidates) — with the scalable cache this clones the
resolved tenant row forward (sQEMU snapshotting), with the vanilla cache
the fork becomes a new fleet tenant whose chain pays the walk on every
table materialization; ``step()`` decodes one token for every active
sequence through ``paged_decode_step``; ``finish_request`` releases a
sequence's blocks back to the pool (tombstoned while forks are live) and
retires its fleet tenant row (``fleet.free_tenant``).

``step()`` performs **zero per-sequence host-side chain walks**. Two
decode paths exist (``decode_path`` ctor arg, default ``"auto"``):

- ``"tables"`` — the COW-prepare mask and the attention block tables
  both come from ONE stacked fleet resolve (``PagedKVCache.prepare_step``)
  — the Pallas kernel plane on lane-aligned pools, the vmapped gather
  otherwise — and the stacked tables ship to the device in one transfer
  per step.
- ``"fused"`` — no padded block tables are materialized at all: a
  *narrow* resolve (``PagedKVCache.prepare_step_fused``, only the
  batch's write columns) stamps the COW slots, then
  ``paged_decode_step_fused`` reads K/V straight through the packed
  (T, C, P) fleet index — the chain walk happens inside the attention
  plane (``kernels/paged_attention``). Auto-selected iff the page axis
  is lane-aligned (``core.fleet.fused_layout_ok``); see
  ``docs/kernels.md`` for the cost model.

The engine can also drive a fleet maintenance plane: pass a
``core.scheduler.MaintenanceScheduler`` and each decode step ends with one
budgeted maintenance tick — background streaming/GC running *beside* the
serving path instead of stopping the world (paper §6.4).

Tiering: ``park_request`` pulls a sequence out of the decode batch and
spills its exclusively-owned KV blocks to host memory
(``PagedKVCache.demote_seq``), freeing device pool blocks for admissions;
``resume_request`` just re-activates it — promotion is *lazy*, paid by
the first ``step()`` whose batch includes the sequence (the decode path's
``prepare_step`` promotes before resolving tables). See
``docs/memory.md`` for the full residency lifecycle.

Golden prefixes: ``register_golden(prompt)`` prefills a prompt once and
freezes it as a shared base; an ``add_request`` whose prompt extends a
registered base (radix-trie probe on token ids) COW-forks the base and
prefills only the suffix — ONE chunked dispatch against the forked
paged prefix (``paged_suffix_prefill``) — so shared-prefix prefill
becomes a fork, costing zero fresh pool blocks and zero prefill FLOPs
for the shared span (``docs/architecture.md``).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import fleet as fleet_lib
from repro.core.golden import PrefixTrie
from repro.kvcache.paged import PagedKVCache, PagedKVConfig
from repro.models import layers as L
from repro.models.api import get_model
from repro.serve.paged_decode import (
    paged_decode_step,
    paged_decode_step_fused,
    paged_suffix_prefill,
)


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, scalable: bool = True,
                 n_blocks: int = 512, block_size: int = 16,
                 max_blocks_per_seq: int = 64, scheduler=None,
                 resolver: str = "auto", decode_path: str = "auto"):
        if cfg.family not in ("dense", "moe"):
            raise ValueError("paged serving engine supports attention LMs")
        if decode_path not in ("auto", "fused", "tables"):
            raise ValueError(f"unknown decode_path {decode_path!r}")
        if decode_path == "auto":
            decode_path = ("fused"
                           if fleet_lib.fused_layout_ok(max_blocks_per_seq)
                           else "tables")
        elif decode_path == "fused" and not fleet_lib.fused_layout_ok(
                max_blocks_per_seq):
            raise ValueError(
                "decode_path='fused' needs a lane-aligned page axis "
                f"(max_blocks_per_seq % 128 == 0, got {max_blocks_per_seq})"
            )
        self.decode_path = decode_path
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.kv = PagedKVCache(
            PagedKVConfig(
                n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd, block_size=block_size, n_blocks=n_blocks,
                max_blocks_per_seq=max_blocks_per_seq,
                dtype=L.COMPUTE_DTYPE,
            ),
            scalable=scalable,
            resolver=resolver,
        )
        self.active: dict[int, list[int]] = {}  # sid -> generated tokens
        self.parked: dict[int, list[int]] = {}  # sid -> tokens, off-batch
        # prefill is jitted ONCE per engine: re-wrapping per request would
        # re-trace (and re-lower) the whole prefill on every admission
        self._jit_prefill = jax.jit(self.model.prefill)
        # golden-prefix registry: the admission-time dedup plane. The trie
        # maps registered prompt token ids -> golden sid; _golden_info
        # keeps each base's prompt (for trie removal) and its predicted
        # first token (an exact-match admission skips the model entirely).
        self._trie = PrefixTrie()
        self._golden_info: dict[int, tuple[tuple[int, ...], int]] = {}
        self.golden_hits = 0   # admissions served by forking a base
        # Scratch block absorbing the in-step pool writes of padded batch
        # rows, so a padded decode can never touch a live sequence's blocks.
        self._pad_block = self.kv.reserve_block()
        # Optional MaintenanceScheduler (core.scheduler) ticked between
        # decode steps — the background half of the serving loop.
        self.scheduler = scheduler
        self.last_maintenance: dict | None = None

    def _prefill_seq(self, prompt_tokens) -> tuple[int, int]:
        """Full-prompt prefill into a fresh sequence: one model prefill,
        one bulk KV append. Returns ``(sid, first_token)``."""
        toks = jnp.asarray(prompt_tokens, jnp.int32)[None]
        logits, cache = self._jit_prefill(self.params, dict(tokens=toks))
        sid = self.kv.new_seq()
        # cache k/v: (L, 1, S, Hkv, D) → (L, S, Hkv, D)
        self.kv.append_prefill(sid, cache["k"][:, 0], cache["v"][:, 0])
        return sid, int(jnp.argmax(logits[0]))

    def add_request(self, prompt_tokens: np.ndarray) -> int:
        """Admit a prompt; returns the sequence id.

        Admission probes the golden-prefix trie first: when a registered
        base's prompt is a prefix of this one, the base is COW-forked —
        the shared prefix contributes ZERO fresh pool blocks and zero
        prefill FLOPs — and only the suffix runs through one chunked
        suffix-prefill dispatch. An exact match skips the model entirely
        (the base's first token was recorded at registration). Without a
        trie hit this is the ordinary full prefill.
        """
        toks = [int(t) for t in np.asarray(prompt_tokens).reshape(-1)]
        depth, gsid = self._trie.longest_prefix(toks)
        if gsid is not None:
            self.golden_hits += 1
            sid = self.kv.fork(gsid)
            suffix = toks[depth:]
            nxt = (self._suffix_prefill(sid, suffix) if suffix
                   else self._golden_info[gsid][1])
            self.active[sid] = [nxt]
            return sid
        sid, first = self._prefill_seq(prompt_tokens)
        self.active[sid] = [first]
        return sid

    def _suffix_prefill(self, sid: int, tokens) -> int:
        """Push a prompt suffix through ONE chunked device dispatch
        against the sequence's paged prefix (``paged_suffix_prefill``)
        and return the first generated token. The chunk is padded to a
        power-of-two bucket — padded rows scatter into the reserved
        scratch block and their outputs are discarded — so admission
        compiles once per bucket, not once per suffix length."""
        s = len(tokens)
        pad = self._bucket(s)
        start = self.kv.seq_length(sid)
        table, blks, offs = self.kv.prepare_span(sid, s)
        fill = self._pad_block
        tbl = np.where(table >= 0, table, fill).astype(np.int32)
        tables = np.broadcast_to(tbl, (pad, tbl.size))
        sb = np.full(pad, fill, np.int32)
        sb[:s] = blks
        so = np.zeros(pad, np.int32)
        so[:s] = offs
        attn_lens = np.ones(pad, np.int32)
        attn_lens[:s] = start + 1 + np.arange(s)
        tok_row = np.zeros((1, pad), np.int32)
        tok_row[0, :s] = tokens
        logits, pk, pv = paged_suffix_prefill(
            self.cfg, self.params, self.kv.pool_k, self.kv.pool_v,
            jnp.asarray(tables), jnp.asarray(sb), jnp.asarray(so),
            jnp.asarray(attn_lens), jnp.asarray(tok_row),
        )
        self.kv.commit_pools(pk, pv)
        self.kv.advance_span(sid, s)
        return int(jnp.argmax(logits[s - 1]))

    def register_golden(self, prompt_tokens: np.ndarray) -> int:
        """Prefill a prompt and freeze it as a golden shared-prefix base.

        The base never joins the decode batch: it exists to be forked by
        later ``add_request`` admissions whose prompts extend its token
        ids. Its KV blocks are frozen device-resident
        (``PagedKVCache.register_golden``) until ``release_golden``.
        Returns the base's sid.
        """
        toks = [int(t) for t in np.asarray(prompt_tokens).reshape(-1)]
        sid, first = self._prefill_seq(prompt_tokens)
        self.kv.register_golden(sid)
        self._trie.insert(toks, sid)
        self._golden_info[sid] = (tuple(toks), first)
        return sid

    def release_golden(self, sid: int) -> None:
        """Retire a golden base: unregister it from the trie and the KV
        plane, then free it. Live forks keep their shared blocks through
        the usual refcounts (the base is tombstoned until the last fork
        frees)."""
        toks, _ = self._golden_info.pop(sid)
        self._trie.remove(list(toks))
        self.kv.release_golden(sid)
        self.kv.free_seq(sid)

    def fork_request(self, sid: int) -> int:
        child = self.kv.fork(sid)   # promotes a parked parent first
        tokens = self.active.get(sid) or self.parked.get(sid) or []
        self.active[child] = list(tokens)
        return child

    def finish_request(self, sid: int) -> None:
        """Retire a finished sequence and release its blocks to the pool.

        Safe with live forks: the cache tombstones the parent until the
        last descendant is freed (``PagedKVCache.free_seq``). Parked
        sequences may finish too — their host-tier spill is dropped with
        them, never promoted.
        """
        if sid in self.active:
            del self.active[sid]
        else:
            del self.parked[sid]
        self.kv.free_seq(sid)

    def park_request(self, sid: int) -> int:
        """Suspend a sequence: drop it from the decode batch and spill its
        exclusively-owned KV blocks to the host tier, freeing device pool
        blocks for other admissions. Shared blocks (live forks, common
        prefixes) stay hot and stay shared. Returns the number of blocks
        spilled (0 is fine — parking is always legal, spilling is
        best-effort)."""
        self.parked[sid] = self.active.pop(sid)
        return self.kv.demote_seq(sid)

    def resume_request(self, sid: int) -> None:
        """Re-activate a parked sequence. Promotion is deliberately NOT
        done here: the first ``step()`` including the sequence promotes
        it inside ``prepare_step``, so a resume costs nothing until the
        sequence actually decodes."""
        self.active[sid] = self.parked.pop(sid)

    def migrate_request_to(self, dst: "Engine", sid: int) -> int:
        """Live-migrate a sequence to another engine; returns its sid
        there.

        The sequence's resolved KV state is exported from this engine's
        cache, imported into ``dst`` as a fresh root (the fork topology
        stays behind — ancestors keep serving their own descendants
        here), bit-verified against the export, and only then retired on
        the source via ``finish_request`` — which tombstones/reaps
        exactly as a normal finish would, so migrating a forked child
        exercises the same cascade. A parked sequence migrates too (its
        host-tier spill is read, never promoted) and lands *active* on
        the destination. Raises ``RuntimeError`` — with the destination
        copy rolled back — if a decode step landed on the source
        mid-migration (stale export) or the landed bytes differ.
        """
        blob = self.kv.export_seq(sid)
        tokens = list(self.active.get(sid) or self.parked.get(sid) or [])
        new_sid = dst.kv.import_seq(blob)
        k, v = dst.kv.gather(new_sid)
        landed_ok = (
            np.asarray(k).view(np.uint8) == blob["k"].view(np.uint8)
        ).all() and (
            np.asarray(v).view(np.uint8) == blob["v"].view(np.uint8)
        ).all()
        stale = self.kv.seq_fingerprint(sid) != blob["fingerprint"]
        if stale or not landed_ok:
            dst.kv.free_seq(new_sid)
            raise RuntimeError(
                f"migration of sid {sid} aborted "
                + ("(source sequence changed mid-migration)" if stale
                   else "(destination KV not bit-identical)")
                + "; source left intact"
            )
        dst.active[new_sid] = tokens
        self.finish_request(sid)
        return new_sid

    @staticmethod
    def _bucket(n: int) -> int:
        """Next power of two: the decode step is compiled once per bucket,
        not once per active-set size (fleet batching, no per-chain re-jit)."""
        b = 1
        while b < n:
            b *= 2
        return b

    def _decode(self, sids, last_tokens) -> dict[int, int]:
        """ONE fleet-batched decode dispatch: COW-prepare, attention,
        pool commit, advance — for ``sids`` feeding ``last_tokens``.
        Returns ``{sid: next_token}``. The device core of ``step()``,
        shared with golden suffix admission (``add_request`` on a trie
        hit), so both paths run the identical compiled step."""
        pad_to = self._bucket(len(sids))
        tok_col = np.zeros((pad_to, 1), np.int32)
        tok_col[: len(sids), 0] = last_tokens
        if self.decode_path == "fused":
            # No table materialization: the narrow COW-prepare resolve
            # stamps this step's write slots, then the decode step reads
            # K/V straight through the stacked fleet index (the chain
            # walk runs inside the attention plane).
            plan = self.kv.prepare_step_fused(
                sids, pad_to=pad_to, pad_block=self._pad_block
            )
            logits, pk, pv = paged_decode_step_fused(
                self.cfg, self.params, self.kv.pool_k, self.kv.pool_v,
                plan.l2, plan.chain_lengths, plan.tenants, plan.lengths,
                plan.write_blocks, jnp.asarray(tok_col),
            )
        else:
            # ONE stacked fleet resolve serves both the COW-prepare mask
            # (the slots the decode step's in-place scatter will hit) and
            # the attention block tables; the sids→tenant-rows mapping
            # ships once. A lone sequence (suffix admission) takes the
            # narrow single-row resolve — O(C·P), not O(T·C·P) — so
            # admission latency stays flat as the fleet fills.
            if len(sids) == 1:
                tables, lengths = self.kv.prepare_step_single(
                    sids[0], pad_to=pad_to, pad_block=self._pad_block
                )
            else:
                tables, lengths = self.kv.prepare_step(
                    sids, pad_to=pad_to, pad_block=self._pad_block
                )
            logits, pk, pv = paged_decode_step(
                self.cfg, self.params, self.kv.pool_k, self.kv.pool_v,
                tables, lengths, jnp.asarray(tok_col),
            )
        self.kv.commit_pools(pk, pv)
        out = {}
        # the sampling boundary: greedy argmax must reach the host to
        # extend python-side sequences — the one designed sync in step()
        nxt = np.asarray(jnp.argmax(logits, axis=-1))  # fleetlint: disable=FL002
        for i, sid in enumerate(sids):
            self.kv.advance(sid)
            out[sid] = int(nxt[i])
        return out

    def step(self) -> dict[int, int]:
        """Decode one token for every active sequence — one fleet-batched
        device dispatch: stacked block tables, padded to a size bucket."""
        sids = sorted(self.active)
        if not sids:
            # an idle engine is the cheapest time for background work —
            # keep draining the maintenance backlog while polling
            self._maintain()
            return {}
        out = self._decode(sids, [self.active[s][-1] for s in sids])
        for sid, tok in out.items():
            self.active[sid].append(tok)
        self._maintain()
        return out

    def _maintain(self) -> None:
        """One budgeted maintenance slice between decode steps: stream/GC
        a few cold tenants instead of ever stopping the world."""
        if self.scheduler is not None:
            self.last_maintenance = self.scheduler.tick()

    def memory_stats(self) -> dict:
        stats = dict(
            blocks_in_use=self.kv.blocks_in_use(),
            host_blocks=self.kv.host_blocks_in_use(),
            lookups=self.kv.lookup_count,
            n_seqs=len(self.active),
            n_parked=len(self.parked),
            golden_hits=self.golden_hits,
            **self.kv.golden_stats(),
        )
        if self.scheduler is not None:
            stats["maintenance"] = self.scheduler.stats()
        return stats
